import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before ANY jax import: jax locks the device
# count at first init. 512 host devices back the 16x16 and 2x16x16 meshes.

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfglib
from repro.core.distributed import DistConfig, assemble, shapes_and_axes
from repro.core.sparsify import SparsifierConfig
from repro.launch import mesh as meshlib
from repro.models import get_family, input_specs
from repro.nn import sharding as shlib
from repro.optim import OptConfig

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "benchmarks", "artifacts")

# per-arch microbatch counts for train_4k (activation-memory control;
# values chosen by the §Perf memory iteration — see EXPERIMENTS.md)
MICROBATCHES = {
    "whisper-tiny": 8,
    "qwen2.5-3b": 4,
    "internvl2-1b": 8,
    "mamba2-780m": 2,
    "chatglm3-6b": 4,
    "zamba2-7b": 4,
    "mixtral-8x7b": 8,
    "deepseek-moe-16b": 8,
    "granite-3-8b": 4,
    "granite-3-8b-swa": 4,
    "phi3-medium-14b": 16,
    "paper-resnet-proxy": 1,
}
# eps/state dtype: bf16 for the param-heavy archs (memory-bound; DESIGN.md)
STATE_DTYPE = {
    "mixtral-8x7b": "bfloat16",
    "phi3-medium-14b": "bfloat16",
    "zamba2-7b": "bfloat16",
    "chatglm3-6b": "bfloat16",
    "granite-3-8b": "bfloat16",
    "granite-3-8b-swa": "bfloat16",
    "deepseek-moe-16b": "bfloat16",
}
ATTN_BLOCK = {"phi3-medium-14b": 512}

COLLECTIVE_RE = re.compile(
    r"=\s*((?:[a-z0-9]+\[[^\]]*\](?:,\s*)?)+|\([^)]*\))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Sum result bytes of every collective op in the (post-SPMD) HLO."""
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo):
        shapes, kind = m.group(1), m.group(2)
        total = 0
        for sm in SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def arch_dist_config(arch: str, mesh, *, sparsity=0.001, aggregation="sparse_allgather",
                     kind="regtopk") -> DistConfig:
    big = arch in STATE_DTYPE  # the param-heavy archs
    return DistConfig(
        sparsifier=SparsifierConfig(kind=kind, sparsity=sparsity, mu=1.0),
        optimizer=OptConfig(
            kind="adam",
            learning_rate=1e-4,
            moment_dtype="bfloat16" if big else "float32",
        ),
        aggregation=aggregation,
        microbatches=MICROBATCHES.get(arch, 4),
        dp_axes=meshlib.dp_axes_of(mesh),
        state_dtype=STATE_DTYPE.get(arch, "float32"),
    )


def zero1_specs(params_shape, param_specs, mesh, dp_axes):
    """ZeRO-1: additionally shard optimizer moments over the dp axes on the
    first dimension not already sharded and divisible by the dp size."""
    dp = tuple(dp_axes)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    dp_entry = dp if len(dp) > 1 else dp[0]

    def mk(shape_leaf, spec):
        dims = shape_leaf.shape
        taken = set()
        for e in spec:
            if e is None:
                continue
            for a in (e if isinstance(e, tuple) else (e,)):
                taken.add(a)
        if any(a in taken for a in dp):
            return spec
        entries = list(spec) + [None] * (len(dims) - len(spec))
        for i, (d, e) in enumerate(zip(dims, entries, strict=True)):
            if e is None and d % dp_size == 0 and d >= dp_size:
                entries[i] = dp_entry
                return P(*entries)
        return spec

    return jax.tree.map(mk, params_shape, param_specs)


def _shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


CFG_OVERRIDES: Dict[str, Any] = {}


def _apply_overrides(cfg):
    if CFG_OVERRIDES:
        kw = {}
        for k, v in CFG_OVERRIDES.items():
            cur = getattr(cfg, k)
            if isinstance(cur, bool):
                v = v in ("1", "true", "True")
            elif isinstance(cur, int) or (cur is None and v.isdigit()):
                v = int(v)
            elif isinstance(cur, float):
                v = float(v)
            kw[k] = v
        cfg = cfg.replace(**kw)
    return cfg


def lower_train(arch: str, shape_name: str, mesh, dist: Optional[DistConfig] = None):
    cfg = cfglib.get_config(arch).replace(dtype="bfloat16")
    if arch in ATTN_BLOCK:
        cfg = cfg.replace(attn_block=ATTN_BLOCK[arch])
    cfg = _apply_overrides(cfg)
    seq, global_batch, _ = cfglib.INPUT_SHAPES[shape_name]
    mod = get_family(cfg)
    dist = dist or arch_dist_config(arch, mesh)
    W = int(np.prod([mesh.shape[a] for a in dist.dp_axes]))
    per_worker = max(1, global_batch // W)
    if dist.microbatches > per_worker:
        dist = __import__("dataclasses").replace(
            dist, microbatches=per_worker
        )
    asm = assemble(mod, cfg, dist, mesh)
    dp = tuple(dist.dp_axes)
    dp_spec = dp if len(dp) > 1 else dp[0]

    batch_specs = input_specs(cfg, global_batch, seq, kind="train")
    batch_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, P(dp_spec)), batch_specs
    )
    from repro.optim import make_optimizer

    opt_shape = jax.eval_shape(
        lambda p: make_optimizer(dist.optimizer).init(p), asm.params_shape
    )
    # moments shard like params + ZeRO-1 over dp where divisible
    mom_specs = zero1_specs(asm.params_shape, asm.param_specs, mesh, dist.dp_axes)
    opt_specs = {
        "step": P(),
        **{k: mom_specs for k in opt_shape if k != "step"},
    }
    in_shardings = (
        _shardings(asm.param_specs, mesh),
        _shardings(opt_specs, mesh),
        _shardings(asm.state_specs, mesh),
        batch_shardings,
    )
    with mesh:
        lowered = jax.jit(
            asm.train_step,
            in_shardings=in_shardings,
            out_shardings=(
                in_shardings[0],
                in_shardings[1],
                in_shardings[2],
                None,
            ),
            # params/opt/sparsifier state are consumed and re-emitted each
            # step -> donation lets XLA reuse their buffers in place
            # (the production trainer does the same).
            donate_argnums=(0, 1, 2),
        ).lower(asm.params_shape, opt_shape, asm.state_shapes, batch_specs)
    return lowered, cfg


def lower_serve(arch: str, shape_name: str, mesh):
    cfg = cfglib.get_config(arch).replace(dtype="bfloat16")
    cfg = _apply_overrides(cfg)
    seq, global_batch, kind = cfglib.INPUT_SHAPES[shape_name]
    mod = get_family(cfg)
    dp = meshlib.dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    M = mesh.shape["model"]

    params_shape, axes = shapes_and_axes(mod, cfg)
    # serve rules: shard kv heads if divisible, else shard the cache seq
    rules = dict()
    if cfg.n_heads and cfg.n_kv_heads % M == 0:
        rules["kv_seq"] = None
    else:
        rules["kv_seq"] = "model"
        rules["kv_heads"] = None
    param_specs = shlib.tree_specs(params_shape, axes, mesh, rules=rules,
                                   dp_axes=dp)

    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    b_spec = dp_spec if global_batch % dp_total == 0 else None
    if kind == "prefill":
        batch_specs = input_specs(cfg, global_batch, seq, kind="train")
        batch_specs.pop("labels")
        batch_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, P(b_spec)), batch_specs
        )

        def serve_step(params, batch):
            return mod.prefill(params, cfg, batch)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(_shardings(param_specs, mesh), batch_shardings),
            ).lower(params_shape, batch_specs)
        return lowered, cfg

    # decode: ONE new token against a seq-length cache
    cache_shape = jax.eval_shape(
        lambda: mod.init_cache(cfg, global_batch, seq)
    )
    cache_ax = mod.cache_axes(cfg)
    cache_specs = shlib.tree_specs(cache_shape, cache_ax, mesh, rules=rules,
                                   dp_axes=dp)
    tok_specs = input_specs(cfg, global_batch, seq, kind="decode")

    def serve_step(params, cache, tokens):
        return mod.decode_step(params, cfg, cache, tokens)

    with mesh:
        lowered = jax.jit(
            serve_step,
            in_shardings=(
                _shardings(param_specs, mesh),
                _shardings(cache_specs, mesh),
                NamedSharding(mesh, P(b_spec)),
            ),
        ).lower(params_shape, cache_shape, tok_specs["tokens"])
    return lowered, cfg


def run_combo(arch: str, shape_name: str, *, multi_pod: bool,
              dist: Optional[DistConfig] = None, tag: str = "") -> Dict[str, Any]:
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    _, _, kind = cfglib.INPUT_SHAPES[shape_name]
    t0 = time.time()
    if kind == "train":
        lowered, cfg = lower_train(arch, shape_name, mesh, dist)
    else:
        lowered, cfg = lower_serve(arch, shape_name, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    from repro.launch import hlo_cost

    walk = hlo_cost.analyze(compiled.as_text())
    colls = walk["collective_bytes"]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "tag": tag,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "mem": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "flops": walk["flops"],
        "hbm_bytes": walk["hbm_bytes"],
        "xla_flops_looponce": cost.get("flops") if cost else None,
        "collective_bytes": colls,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--aggregation", default="sparse_allgather")
    ap.add_argument("--sparsifier", default="regtopk")
    ap.add_argument("--sparsity", type=float, default=0.001)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None)
    ap.add_argument("--cfg", action="append", default=[],
                    help="model-config override key=value (repeatable)")
    args = ap.parse_args()
    for item in args.cfg:
        k, v = item.split("=", 1)
        CFG_OVERRIDES[k] = v

    archs = (
        [a for a in cfglib.ARCHS if a != "paper-resnet-proxy"]
        if args.arch == "all"
        else [args.arch]
    )
    shapes = (
        list(cfglib.INPUT_SHAPES) if args.shape == "all" else [args.shape]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out_path = args.out or os.path.join(
        os.path.abspath(ARTIFACT), "dryrun.jsonl"
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)

    done = set()
    if os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("tag", "")))
                except Exception:
                    pass

    n_fail = 0
    for multi in meshes:
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                if not cfglib.shape_applicable(arch, shape):
                    print(f"SKIP  {arch} x {shape} (see DESIGN.md)", flush=True)
                    continue
                if (arch, shape, mesh_name, args.tag) in done:
                    print(f"CACHED {arch} x {shape} x {mesh_name}", flush=True)
                    continue
                try:
                    dist = None
                    if args.tag:
                        m = meshlib.make_production_mesh(multi_pod=multi)
                        dist = arch_dist_config(
                            arch, m, sparsity=args.sparsity,
                            aggregation=args.aggregation, kind=args.sparsifier,
                        )
                    rec = run_combo(
                        arch, shape, multi_pod=multi, dist=dist, tag=args.tag
                    )
                    with open(out_path, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                    peak = rec["mem"]["peak_bytes"]
                    print(
                        f"OK    {arch} x {shape} x {mesh_name}: "
                        "peak={:.2f}GiB ".format(peak and peak / 2**30)
                        + f"flops={rec['flops']:.3e} "
                        f"coll={rec['collective_bytes']['total']:.3e}B "
                        f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                        flush=True,
                    )
                except Exception as e:
                    n_fail += 1
                    print(f"FAIL  {arch} x {shape} x {mesh_name}: {e}", flush=True)
                    traceback.print_exc()
    print(f"dry-run complete; {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
