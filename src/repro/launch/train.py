"""Training launcher.

CPU/dev:    PYTHONPATH=src python -m repro.launch.train --arch paper-resnet-proxy \
                --steps 50 --global-batch 8 --seq 64
Production: run under a TPU runtime where ``jax.devices()`` exposes the
            16x16 (or 2x16x16 with --multi-pod) slice; the same flags apply
            with --mesh production.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.checkpoint import restore, save
from repro.core.distributed import (
    DistConfig,
    assemble,
    comm_round_bytes,
    comm_round_cost,
    init_sparsifier_state,
)
from repro.core.sparsify import SparsifierConfig
from repro.data import TokenPipeline
from repro.launch import mesh as meshlib
from repro.models import get_family
from repro.optim import OptConfig, make_optimizer


def _replan(dist, mesh, dp_axes, plan, step_fn, sp_state, mod, cfg, asm, t):
    """Mid-training re-plan (--replan-every): probe the live collectives,
    fit a fresh alpha-beta model from the measured samples, re-run the
    per-leaf (codec x collective) planning at the k actually being sent,
    and rebuild the jitted step on the regrafted plan. Capacities (and so
    every state shape) are untouched — training resumes in place."""
    from collections import Counter

    from repro import comm
    from repro.comm import calibrate as cal
    from repro.core.distributed import (
        apply_plan_decisions,
        leaf_wire,
        make_train_step,
    )

    res = cal.calibrate(mesh=mesh, dp_axes=dp_axes)
    if not res.calibrated:
        print(
            f"replan @step {t + 1}: skipped (no dp axis with >1 worker)",
            flush=True,
        )
        return plan, step_fn
    W = int(np.prod([mesh.shape[a] for a in dp_axes]))
    part = dist.resolved_participation()
    k_over = None
    if dist.resolved_adaptive_k() is not None:
        k_over = jax.tree.map(
            lambda c: int(c.k),
            sp_state[1],
            is_leaf=lambda x: isinstance(x, comm.ControllerState),
        )
    cp = comm.replan(
        plan,
        [mesh.shape[a] for a in dp_axes],
        res.samples,
        k_overrides=k_over,
        codecs=None if dist.codec == "auto" else [dist.codec],
        collectives=(
            None if dist.resolved_collective() == "auto"
            else [dist.resolved_collective()]
        ),
        allow_lossy=dist.codec != "auto",
        participants=(
            part.expected_participants(W) if part is not None else None
        ),
        fastpath=dist.resolved_fastpath(),
    )
    new_plan = apply_plan_decisions(plan, cp)
    lk = cp.model.links[0]
    print(
        f"replan @step {t + 1}: alpha={lk.alpha:.3e} s/msg "
        f"beta={lk.beta:.3e} s/B -> "
        f"{cp.total_seconds * 1e3:.3f} ms/round predicted",
        flush=True,
    )
    picks = Counter(
        leaf_wire(p, dist)
        for p in jax.tree.leaves(
            new_plan, is_leaf=lambda x: hasattr(x, "local_len")
        )
    )
    for (c, s), n in sorted(picks.items()):
        print(f"replan:   {c}/{s}: {n} leaves", flush=True)
    step = jax.jit(make_train_step(
        mod, cfg, dist, mesh, asm.param_specs, new_plan, asm.state_specs
    ))
    return new_plan, step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-resnet-proxy")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sparsifier", default="regtopk",
                    choices=["none", "topk", "regtopk", "cyclic"])
    ap.add_argument("--sparsity", type=float, default=0.01)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--aggregation", default="sparse_allgather",
                    help="legacy alias for --collective")
    ap.add_argument("--codec", default="coo_fp32",
                    choices=["coo_fp32", "coo_idx_delta", "bitmap_dense",
                             "coo_q8", "auto"],
                    help="'auto' plans per leaf via the alpha-beta model")
    ap.add_argument("--collective", default=None,
                    choices=["dense_allreduce", "sparse_allgather",
                             "hierarchical", "auto"])
    ap.add_argument("--fastpath", default="off",
                    choices=["off", "on", "auto"],
                    help="fused Pallas select->encode pipeline: 'on' "
                         "fuses every fusable leaf (bit-for-bit, with a "
                         "runtime exactness fallback), 'auto' fuses the "
                         "leaves the measured-throughput table prices "
                         "faster (resolves to 'off' off-TPU)")
    ap.add_argument("--link-topo", default=None, metavar="SPEC",
                    help="per-dp-axis link model for auto-planning: "
                         "';'-separated 'class:alpha,beta' entries where "
                         "class is a dp axis name or 'intra'/'inter' "
                         "(e.g. 'intra:1e-6,1e-11;inter:1e-5,1e-10'), or a "
                         "bare 'alpha,beta' for a uniform model")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the alpha-beta link model from real "
                         "collectives before auto-planning (per dp axis "
                         "on multi-axis meshes; ignored when --link-topo "
                         "is given)")
    ap.add_argument("--participation", default=None, metavar="SPEC",
                    help="partial-participation schedule over the dp "
                         "worker group: 'full' (default), "
                         "'bernoulli:drop_rate[,seed]', "
                         "'round_robin:n_stragglers', or "
                         "'sampled:S[,seed]' (S-of-N client sampling via "
                         "a common-knowledge PRNG) — dropped workers "
                         "keep their payload in the error accumulator and "
                         "the round aggregates with renormalized weights "
                         "('stale:...' bounded-staleness delivery is "
                         "simulator-only)")
    ap.add_argument("--coord-weights", action="store_true",
                    help="per-coordinate aggregation weights: renormalize "
                         "each coordinate by the mass of the workers that "
                         "actually sent it instead of one per-worker "
                         "scalar (weighting='coordinate'; implies "
                         "fastpath stays off for regtopk)")
    ap.add_argument("--adaptive-k", default=None, metavar="SPEC",
                    help="error-budget-driven per-round k: "
                         "'budget[,k_min,k_max]' — the controller grows/"
                         "shrinks each leaf's k to hold "
                         "||eps||/||g_agg|| at the budget; bounds in "
                         "(0,1) are fractions of the leaf length, >= 1 "
                         "absolute counts; payloads ride at the k_max "
                         "capacity so k changes never retrace")
    ap.add_argument("--overlap", default="off", metavar="SPEC",
                    help="bucketed overlap schedule: 'off' (synchronous "
                         "round, the historical program) or 'buckets:B' — "
                         "split the leaf tree into B size-balanced launch "
                         "buckets so hierarchical's slow inter-axis stage "
                         "pipelines behind the next bucket's intra-axis "
                         "work; numerics are bit-for-bit identical either "
                         "way (metrics gain per-bucket 'timeline' stamps)")
    ap.add_argument("--replan-every", type=int, default=0, metavar="N",
                    help="every N steps, re-fit the alpha-beta link model "
                         "from live collective probes and re-plan the "
                         "per-leaf codec/collective choices from the "
                         "measured samples (0 disables)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of --arch")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = cfglib.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_variant()
    if args.mesh == "production":
        mesh = meshlib.make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = meshlib.make_host_mesh(model=args.model_parallel)
    dp_axes = meshlib.dp_axes_of(mesh)
    W = int(np.prod([mesh.shape[a] for a in dp_axes]))
    if args.global_batch % W:
        raise SystemExit(f"--global-batch must be divisible by {W} workers")

    link_model = None
    link_topo = None
    if args.link_topo:
        from repro import comm

        link_topo = comm.parse_link_topo(args.link_topo, dp_axes)
        for ax, lk in zip(dp_axes, link_topo.links, strict=True):
            print(
                f"link-topo {ax}: alpha={lk.alpha:.3e} s/msg "
                f"beta={lk.beta:.3e} s/B",
                flush=True,
            )
        if args.calibrate:
            print("--link-topo given; skipping --calibrate", flush=True)
    elif args.calibrate:
        from repro.comm import calibrate as cal

        if len(dp_axes) > 1:
            res = cal.calibrate_topo(mesh=mesh, dp_axes=dp_axes)
            if res.calibrated:
                link_topo = res.topo
                for ax, c in zip(res.axes, res.per_axis, strict=True):
                    print(
                        f"calibrated {ax}: alpha={c.model.alpha:.3e} s/msg "
                        f"beta={c.model.beta:.3e} s/B "
                        f"(rms {c.residual:.2e}s over {len(c.samples)} "
                        "probes)"
                        if c.calibrated
                        else f"calibrated {ax}: size-1 axis, defaults kept",
                        flush=True,
                    )
            else:
                print(
                    "calibration skipped (no dp axis with >1 worker); "
                    "using defaults",
                    flush=True,
                )
        else:
            res = cal.calibrate(mesh=mesh, dp_axes=dp_axes)
            link_model = res.model
            print(
                f"calibrated alpha={link_model.alpha:.3e} s/msg "
                f"beta={link_model.beta:.3e} s/B "
                f"(rms {res.residual:.2e}s over {len(res.samples)} probes)"
                if res.calibrated
                else "calibration skipped (single device); using defaults",
                flush=True,
            )

    participation = None
    if args.participation:
        from repro import comm

        participation = comm.parse_participation(args.participation)
        participation.validate(W)
        if not participation.is_full:
            print(
                f"participation: {participation.kind} — expected "
                f"{participation.expected_participants(W):.2f}/{W} workers "
                "on time per round (renormalized weights)",
                flush=True,
            )

    adaptive_k = None
    if args.adaptive_k:
        from repro import comm

        adaptive_k = comm.parse_adaptive_k(args.adaptive_k)
        print(
            f"adaptive-k: budget={adaptive_k.budget:g} "
            f"bounds=[{adaptive_k.k_min:g}, {adaptive_k.k_max:g}] "
            f"momentum={adaptive_k.momentum:g} "
            f"hysteresis={adaptive_k.hysteresis:g}",
            flush=True,
        )

    dist = DistConfig(
        sparsifier=SparsifierConfig(
            kind=args.sparsifier, sparsity=args.sparsity, mu=args.mu
        ),
        optimizer=OptConfig(kind="adam", learning_rate=args.lr),
        aggregation=args.aggregation,
        codec=args.codec,
        collective=args.collective,
        microbatches=args.microbatches,
        dp_axes=dp_axes,
        link_model=link_model,
        link_topo=link_topo,
        participation=participation,
        fastpath=args.fastpath,
        adaptive_k=adaptive_k,
        weighting="coordinate" if args.coord_weights else "worker",
        overlap=args.overlap,
    )
    if args.coord_weights:
        print(
            "weighting: coordinate — per-coordinate renormalization over "
            "the workers that sent each coordinate",
            flush=True,
        )
    if args.fastpath != "off":
        print(
            f"fastpath: {args.fastpath} (resolved "
            f"{dist.resolved_fastpath()}) — fused select->encode on "
            "fusable leaves",
            flush=True,
        )
    mod = get_family(cfg)
    asm = assemble(mod, cfg, dist, mesh)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(dist.optimizer)
    opt_state = opt.init(params)
    sp_state, _ = init_sparsifier_state(
        asm.plan, W, mesh, dp_axes, jnp.float32
    )
    if adaptive_k is not None:
        from repro.core.distributed import init_controller_state

        ctrl0, _ = init_controller_state(asm.plan, dist)
        sp_state = (sp_state, ctrl0)
    start = 0
    if args.resume:
        params = restore(args.resume + "/params", params)
        opt_state = restore(args.resume + "/opt", opt_state)
        sp_state = restore(args.resume + "/sparsifier", sp_state)
        from repro.checkpoint.store import metadata

        start = metadata(args.resume + "/params").get("step", 0)
        print(f"resumed from step {start}")

    pipe = TokenPipeline(cfg, args.global_batch, args.seq)
    step_fn = jax.jit(asm.train_step)
    pred_b, meas_b = comm_round_bytes(asm.plan, dist, mesh)
    round_cost = comm_round_cost(asm.plan, dist, mesh)
    print(
        f"comm: codec={dist.codec} collective={dist.resolved_collective()} "
        f"{meas_b / 1e6:.3f} MB/worker/round "
        f"(predicted {pred_b / 1e6:.3f} MB, "
        f"{round_cost.seconds * 1e3:.3f} ms/round under the link model)",
        flush=True,
    )
    if dist.resolved_overlap() is not None:
        from repro.core.distributed import comm_round_timeline

        bplan, tline = comm_round_timeline(asm.plan, dist, mesh)
        print(
            f"comm:   overlap {bplan.n_buckets} buckets "
            f"({dist.overlap}): {tline.sync_seconds * 1e3:.3f} ms sync -> "
            f"{tline.seconds * 1e3:.3f} ms overlapped",
            flush=True,
        )
    if dist.codec == "auto" or dist.resolved_collective() == "auto":
        from collections import Counter

        from repro.core.distributed import LeafPlan, leaf_wire

        picks = Counter(
            leaf_wire(p, dist)
            for p in jax.tree.leaves(
                asm.plan, is_leaf=lambda x: isinstance(x, LeafPlan)
            )
        )
        for (c, s), n in sorted(picks.items()):
            print(f"comm:   auto-plan {c}/{s}: {n} leaves", flush=True)
    if dist.resolved_fastpath() != "off":
        from repro.core.distributed import LeafPlan, leaf_fastpath

        leaves = jax.tree.leaves(
            asm.plan, is_leaf=lambda x: isinstance(x, LeafPlan)
        )
        n_fused = sum(leaf_fastpath(p, dist) for p in leaves)
        print(
            f"comm:   fastpath: {n_fused}/{len(leaves)} leaves fused",
            flush=True,
        )
    plan = asm.plan
    t0 = time.time()
    with mesh:
        for t in range(start, start + args.steps):
            params, opt_state, sp_state, m = step_fn(
                params, opt_state, sp_state, pipe.batch_at(t)
            )
            if t % args.log_every == 0 or t == start + args.steps - 1:
                dt = time.time() - t0
                extra = (
                    f" k {float(m['adaptive_k']):7.1f}"
                    if "adaptive_k" in m else ""
                )
                print(
                    f"step {t:5d} loss {float(m['loss']):.4f}{extra} "
                    f"({dt / max(1, t - start + 1):.2f}s/step)",
                    flush=True,
                )
            is_last = t == start + args.steps - 1
            if (
                args.replan_every
                and not is_last
                and (t - start + 1) % args.replan_every == 0
            ):
                plan, step_fn = _replan(
                    dist, mesh, dp_axes, plan, step_fn, sp_state,
                    mod, cfg, asm, t,
                )
    if args.checkpoint:
        save(args.checkpoint + "/params", params,
             metadata={"step": start + args.steps})
        save(args.checkpoint + "/opt", opt_state)
        save(args.checkpoint + "/sparsifier", sp_state)
        print(f"checkpointed to {args.checkpoint}")


if __name__ == "__main__":
    main()
