"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE — useless for
scan-over-layers models. This walker parses the HLO text, extracts each
while loop's trip count from its condition computation (the `lt(iter,
constant)` pattern lax.scan emits), and walks the call graph multiplying
body costs by trip counts. It reports, per device:

  * ``flops``            — dot/convolution MACs x2 (dominant terms)
  * ``collective_bytes`` — per collective kind, result-shape bytes
  * ``hbm_bytes``        — 2 x Σ materialized result bytes (read+write
                           proxy; fusion internals excluded, as on TPU)

Approximations are documented in EXPERIMENTS.md §Roofline (methodology).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "f8e4m3fn": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_CALL_REF = re.compile(
    r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND = re.compile(r"%?([\w.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "after-all", "partition-id",
}


def _shape_bytes(typestr: str) -> int:
    total = 0
    for m in _SHAPE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(typestr: str) -> List[int]:
    m = _SHAPE.search(typestr)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Op:
    __slots__ = ("name", "type", "kind", "rest")

    def __init__(self, name, type_, kind, rest):
        self.name, self.type, self.kind, self.rest = name, type_, kind, rest


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = h.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur].append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _trip_count(cond_ops: List[Op]) -> int:
    """Largest s32 scalar constant in the loop condition ~= trip count."""
    best = 1
    for op in cond_ops:
        if op.kind == "constant" and op.type.strip().startswith("s32[]"):
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
        m = _CONST_S32.search(op.type + " " + op.rest)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.type):
        out_elems *= d
    # contraction size from lhs operand shape + contracting dims. The lhs
    # operand is the text up to the first ", " — either "%name" (newer HLO
    # text) or "f32[8,8]{1,0} %name" (older dialects inline the type).
    mc = _CONTRACT.search(op.rest)
    k = 1
    if mc:
        lhs = op.rest.split(", ")[0]
        dims = _shape_dims(lhs)  # inline-typed operand
        if not dims:
            first = _OPERAND.match(lhs.strip().lstrip("(").lstrip("%"))
            if first:
                dims = _shape_dims(symtab.get(first.group(1), ""))
        for ci in mc.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        # entry = the computation named like ENTRY (first with ENTRY kept by
        # regex order); fall back: computation not referenced by others.
        referenced = set()
        for ops in self.comps.values():
            for op in ops:
                for r in _CALL_REF.finditer(op.rest):
                    referenced.add(r.group(1))
                b = _BRANCHES.search(op.rest)
                if b:
                    for name in b.group(1).split(","):
                        referenced.add(name.strip().lstrip("%"))
        entries = [c for c in self.comps if c not in referenced]
        self.entry = entries[-1] if entries else next(iter(self.comps))
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def _comp_cost(self, name: str) -> Tuple[float, float, Dict[str, float]]:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = (0.0, 0.0, {})  # cycle guard
        ops = self.comps.get(name, [])
        symtab = {op.name: op.type for op in ops}
        flops = 0.0
        bytes_ = 0.0
        colls: Dict[str, float] = {}
        for op in ops:
            if op.kind == "dot":
                flops += _dot_flops(op, symtab)
            elif op.kind == "convolution":
                out = 1
                for d in _shape_dims(op.type):
                    out *= d
                flops += 2.0 * out * 8  # depthwise K=4 approx (x2 MAC)
            if op.kind in COLLECTIVES or any(
                op.kind.startswith(c + "-") for c in COLLECTIVES
            ):
                base = op.kind
                for c in COLLECTIVES:
                    if op.kind.startswith(c):
                        base = c
                        break
                b = _shape_bytes(op.type)
                colls[base] = colls.get(base, 0.0) + b
            if op.kind not in _SKIP_BYTES:
                bytes_ += _shape_bytes(op.type)
            # recurse into referenced computations
            if op.kind == "while":
                body = cond = None
                for m in _CALL_REF.finditer(op.rest):
                    key = m.group(0).split("=")[0]
                    if key == "body":
                        body = m.group(1)
                    elif key == "condition":
                        cond = m.group(1)
                trips = _trip_count(self.comps.get(cond, [])) if cond else 1
                if body:
                    bf, bb, bc = self._comp_cost(body)
                    flops += trips * bf
                    bytes_ += trips * bb
                    for k, v in bc.items():
                        colls[k] = colls.get(k, 0.0) + trips * v
            elif op.kind == "conditional":
                b = _BRANCHES.search(op.rest)
                if b:
                    branch_costs = [
                        self._comp_cost(n.strip().lstrip("%"))
                        for n in b.group(1).split(",")
                    ]
                    if branch_costs:
                        bf = max(c[0] for c in branch_costs)
                        bb = max(c[1] for c in branch_costs)
                        flops += bf
                        bytes_ += bb
                        for c in branch_costs:
                            for k, v in c[2].items():
                                colls[k] = max(colls.get(k, 0.0), v)
            else:
                for m in _CALL_REF.finditer(op.rest):
                    key = m.group(0).split("=")[0]
                    if key in ("to_apply", "calls"):
                        cf, cb, cc = self._comp_cost(m.group(1))
                        flops += cf
                        # fusion internals don't hit HBM; count calls only
                        if op.kind != "fusion":
                            bytes_ += cb
                        for k, v in cc.items():
                            colls[k] = colls.get(k, 0.0) + v
        self._memo[name] = (flops, bytes_, colls)
        return self._memo[name]

    def analyze(self) -> Dict[str, object]:
        flops, bytes_, colls = self._comp_cost(self.entry)
        colls = dict(colls)
        colls["total"] = sum(colls.values())
        return {
            "flops": flops,
            "hbm_bytes": 2.0 * bytes_,
            "collective_bytes": colls,
        }


def analyze(hlo: str) -> Dict[str, object]:
    return HloCost(hlo).analyze()
