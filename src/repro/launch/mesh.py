"""Production mesh construction (TPU v5e; 16x16 pod, 2-pod multi-pod).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required: smoke tests see 1 CPU device, only the
dry-run forces 512 host devices via XLA_FLAGS before any jax import).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Degenerate mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"))


def dp_axes_of(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
