"""Batched decode serving driver.

Initializes (or restores) a model, builds the KV/SSM cache, and decodes
batched requests token-by-token, reporting tokens/s. CPU-runnable with
--smoke; the production path lowers the same ``serve_step``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.checkpoint import restore
from repro.models import get_family


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--restore", default=None)
    args = ap.parse_args()

    cfg = cfglib.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_variant()
    mod = get_family(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    if args.restore:
        params = restore(args.restore + "/params", params)

    cache = mod.init_cache(cfg, args.batch, args.max_len)
    if cfg.family == "encdec":
        frames = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        ck, cv = mod.build_cross_cache(params, cfg, frames)
        cache.update({"ck": ck, "cv": cv})

    step = jax.jit(lambda p, c, t: mod.decode_step(p, cfg, c, t))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)

    # prefill via decode steps (teacher forcing the prompt)
    for _t in range(args.prompt_len):
        logits, cache = step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tokens)

    t0 = time.time()
    out = []
    for _t in range(args.new_tokens):
        logits, cache = step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tokens)
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    total = args.new_tokens * args.batch
    print(
        f"{args.arch}: decoded {total} tokens in {dt:.2f}s "
        f"({total / dt:.1f} tok/s, batch={args.batch})"
    )
    print("sample token ids:", [int(x[0, 0]) for x in out[:8]])


if __name__ == "__main__":
    main()
