"""Minimal pytree-native NN layer library with logical sharding axes.

Parameters are nested dicts of ``jax.Array``; every init function returns a
matching tree of *logical axis* tuples (strings) that
:mod:`repro.nn.sharding` resolves to mesh ``PartitionSpec`` s. No framework
dependency — pure JAX, scan-stacked layers.
"""
from repro.nn import layers, moe, sharding, ssd
