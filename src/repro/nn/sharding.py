"""Logical-axis → mesh-axis resolution (MaxText-style sharding rules).

Every parameter init returns, alongside the array tree, a tree of logical
axis name tuples, e.g. ``("embed", "mlp")`` for an MLP up-projection. This
module maps those names onto physical mesh axes, with a divisibility guard:
a logical axis is sharded only if its size is divisible by the mesh axis it
would map to (otherwise replicated — e.g. phi3's 40 heads on a 16-way model
axis stay replicated while its 17920-wide FFN shards).
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# default logical → mesh-axis rules; batch-like axes go to data parallel.
DEFAULT_RULES: Dict[str, Optional[str]] = {
    # tensor-parallel candidates
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "moe_mlp": "model",
    "d_inner": "model",
    "ssm_heads": "model",
    "experts": None,  # default: tensor-parallel MoE (experts replicated)
    "experts_sharded": "model",  # expert-parallel layout
    # replicated
    "embed": None,
    "layers": None,
    "blocks": None,
    "head_dim": None,
    "ssm_state": None,
    "conv": None,
    "expert_in": None,
    # data-parallel (activations)
    "batch": "__dp__",  # placeholder resolved to the dp axes tuple
    "worker": "__dp__",
    "seq": None,
}


def resolve_spec(
    logical_axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: jax.sharding.Mesh,
    rules: Optional[Mapping[str, Optional[str]]] = None,
    dp_axes: Tuple[str, ...] = ("data",),
) -> P:
    """Resolve one parameter's logical axes to a PartitionSpec.

    Divisibility guard: if ``shape[i]`` is not divisible by the mesh axis
    size (product for dp tuples), that dim is replicated instead.
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    out = []
    for dim, name in enumerate(logical_axes):
        mesh_ax = rules.get(name) if name is not None else None
        if mesh_ax is None:
            out.append(None)
            continue
        if mesh_ax == "__dp__":
            size = 1
            for ax in dp_axes:
                size *= mesh.shape[ax]
            if shape[dim] % size == 0 and shape[dim] >= size:
                out.append(tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0])
            else:
                out.append(None)
            continue
        if shape[dim] % mesh.shape[mesh_ax] == 0 and shape[dim] >= mesh.shape[mesh_ax]:
            out.append(mesh_ax)
        else:
            out.append(None)
    # PartitionSpec forbids trailing Nones being meaningful; fine to keep.
    return P(*out)


def tree_specs(
    params: object,
    axes: object,
    mesh: jax.sharding.Mesh,
    rules: Optional[Mapping[str, Optional[str]]] = None,
    dp_axes: Tuple[str, ...] = ("data",),
):
    """Map a (params, logical-axes) tree pair to a PartitionSpec tree."""
    return jax.tree.map(
        lambda p, ax: resolve_spec(tuple(ax), p.shape, mesh, rules, dp_axes),
        params,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


def tree_shardings(specs, mesh: jax.sharding.Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
