"""Mixture-of-Experts layer (GShard-style one-hot dispatch, TPU-native).

Supports the two assigned MoE architectures:
  * mixtral-8x7b       — 8 experts, top-2, no shared experts
  * deepseek-moe-16b   — 64 fine-grained routed experts top-6 + 2 shared

Design notes (TPU adaptation):
  * capacity-based token dropping with one-hot dispatch/combine einsums —
    static shapes, MXU-friendly (the standard GShard/Switch TPU pattern).
  * tokens are processed in groups (scan) so the [Sg, E, C] dispatch tensor
    never materializes for the full batch.
  * two parallelism layouts:
      - "tensor": experts replicated, expert-FFN hidden dim sharded on
        "model" (no all-to-all; default)
      - "expert": experts sharded on "model" (expert parallelism; XLA
        inserts all-to-all for dispatch/combine) — requires E % shards == 0
  * auxiliary load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    *,
    n_shared: int = 0,
    shared_d_ff: Optional[int] = None,
    parallelism: str = "tensor",
    dtype=jnp.float32,
) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 8)
    s = d_model**-0.5
    so = d_ff**-0.5
    e_ax = "experts" if parallelism == "tensor" else "experts_sharded"
    f_ax = "moe_mlp" if parallelism == "tensor" else None
    p = {
        "router": s * jax.random.normal(ks[0], (d_model, n_experts), dtype),
        "wg": s * jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype),
        "wu": s * jax.random.normal(ks[2], (n_experts, d_model, d_ff), dtype),
        "wd": so * jax.random.normal(ks[3], (n_experts, d_ff, d_model), dtype),
    }
    a = {
        "router": ("embed", None),
        "wg": (e_ax, "embed", f_ax),
        "wu": (e_ax, "embed", f_ax),
        "wd": (e_ax, f_ax, "embed"),
    }
    if n_shared:
        sf = shared_d_ff or (n_shared * d_ff)
        sso = sf**-0.5
        p["shared"] = {
            "wg": s * jax.random.normal(ks[4], (d_model, sf), dtype),
            "wu": s * jax.random.normal(ks[5], (d_model, sf), dtype),
            "wd": sso * jax.random.normal(ks[6], (sf, d_model), dtype),
        }
        a["shared"] = {
            "wg": ("embed", "mlp"),
            "wu": ("embed", "mlp"),
            "wd": ("mlp", "embed"),
        }
    return p, a


def _expert_ffn(p: Params, xe: jax.Array) -> jax.Array:
    """xe: [E, C, D] -> [E, C, D] (SwiGLU per expert)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(xe.dtype))


def moe_apply(
    p: Params,
    x: jax.Array,  # [B, S, D]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 4096,
    router_dtype=jnp.float32,
    no_drop: bool = False,
    dispatch: str = "einsum",  # "einsum" (GShard) | "gather" (ours, §Perf)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux load-balance loss scalar).

    ``no_drop=True`` sets capacity = group size (decode/serving must never
    drop a token; capacity-based dropping is a training-only trade-off).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    xt = x.reshape(B * S, D)
    T = xt.shape[0]
    g = min(group_size, T)
    n_groups = -(-T // g)
    pad = n_groups * g - T
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(n_groups, g, D)
    cap = g if no_drop else max(1, int(g * top_k * capacity_factor / E))

    def per_group(xs):
        logits = (xs.astype(router_dtype) @ p["router"].astype(router_dtype))
        probs = jax.nn.softmax(logits, axis=-1)  # [g, E]
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # [g, k]
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )
        # position of each (token, choice) within its expert's buffer
        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # [g, k, E]
        flatoh = onehot.reshape(g * top_k, E)
        pos = jnp.cumsum(flatoh, axis=0) - flatoh  # [g*k, E]
        pos = (pos * flatoh).sum(-1).reshape(g, top_k)  # [g, k]
        keep = pos < cap
        if dispatch == "gather":
            # §Perf hillclimb (deepseek-moe): scatter/gather row dispatch.
            # The one-hot dispatch/combine EINSUMS cost 2·g·E·cap·D MACs
            # each — ~20-300x the expert FFN itself for fine-grained MoE.
            # Row scatter into the expert buffers (slots are unique by
            # construction) + weighted row gather back are pure data
            # movement: no MXU flops at all.
            slot = gate_idx * cap + pos  # [g, k] unique where keep
            slot = jnp.where(keep, slot, E * cap)  # park dropped tokens
            tok = jnp.broadcast_to(
                jnp.arange(g)[:, None], (g, top_k)
            ).reshape(-1)
            xe_flat = (
                jnp.zeros((E * cap + 1, D), xs.dtype)
                .at[slot.reshape(-1)]
                .set(xs[tok])
            )[: E * cap]
            ye = _expert_ffn(p, xe_flat.reshape(E, cap, D))
            ye_flat = jnp.concatenate(
                [ye.reshape(E * cap, D), jnp.zeros((1, D), ye.dtype)], 0
            )
            picked = ye_flat[slot.reshape(-1)].reshape(g, top_k, D)
            out = jnp.einsum(
                "gk,gkd->gd",
                (gate_vals * keep).astype(xs.dtype),
                picked,
            )
        else:  # "einsum": classical GShard one-hot matmul dispatch
            disp = jnp.zeros((g, E, cap), xs.dtype)
            comb = jnp.zeros((g, E, cap), xs.dtype)
            for c in range(top_k):  # static tiny loop over choices
                oh = (
                    jax.nn.one_hot(gate_idx[:, c], E, dtype=xs.dtype)[:, :, None]
                    * jax.nn.one_hot(pos[:, c], cap, dtype=xs.dtype)[:, None, :]
                )
                oh = oh * keep[:, c, None, None].astype(xs.dtype)
                disp = disp + oh
                comb = comb + oh * gate_vals[:, c, None, None].astype(xs.dtype)
            xe = jnp.einsum("tec,td->ecd", disp, xs)  # [E, cap, D]
            ye = _expert_ffn(p, xe)
            out = jnp.einsum("tec,ecd->td", comb, ye)  # [g, D]
        # Switch aux loss: E * sum_e f_e * p_e
        f_e = onehot.sum((0, 1)).astype(router_dtype) / (g * top_k)
        p_e = probs.mean(0)
        aux = E * jnp.sum(f_e * p_e)
        return out, aux

    if n_groups == 1:
        out, aux = per_group(xg[0])
        outs, auxs = out[None], aux[None]
    else:
        outs, auxs = jax.lax.map(per_group, xg)
    y = outs.reshape(n_groups * g, D)[:T].reshape(B, S, D)
    if "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["wg"].astype(x.dtype)) * (
            x @ sp["wu"].astype(x.dtype)
        )
        y = y + h @ sp["wd"].astype(x.dtype)
    return y, auxs.mean()
