"""Mamba2 / SSD (state-space duality) block in pure JAX (arXiv:2405.21060).

TPU adaptation (see DESIGN.md): the SSD *chunked* algorithm is exactly the
MXU-friendly formulation — intra-chunk work is dense Q×Q matmuls, the
inter-chunk recurrence is a short ``lax.scan`` over chunk states. We keep
chunk length a config knob (roofline lever: larger chunks → more MXU work
per HBM byte, more FLOPs wasted on the masked triangle).

Shapes: x [B, S, E]; inner: heads H = d_inner / headdim P; state N.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

CONV_K = 4  # depthwise causal conv kernel width (mamba2 default)


def ssd_init(
    key,
    d_model: int,
    *,
    d_inner: int,
    headdim: int = 64,
    d_state: int = 128,
    dtype=jnp.float32,
) -> Tuple[Params, Params]:
    H = d_inner // headdim
    conv_dim = d_inner + 2 * d_state  # x, B, C share the conv (ngroups=1)
    ks = jax.random.split(key, 5)
    s = d_model**-0.5
    p = {
        "in_proj": s
        * jax.random.normal(
            ks[0], (d_model, 2 * d_inner + 2 * d_state + H), dtype
        ),
        "conv_w": 0.1 * jax.random.normal(ks[1], (CONV_K, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": (d_inner**-0.5)
        * jax.random.normal(ks[2], (d_inner, d_model), dtype),
    }
    a = {
        "in_proj": ("embed", "d_inner"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("d_inner",),
        "out_proj": ("d_inner", "embed"),
    }
    return p, a


def _split(p: Params, zxbcdt: jax.Array, d_inner: int, d_state: int, H: int):
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * d_state], axis=-1
    )
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: xbc [B,S,C], w [K,C] -> [B,S,C]."""
    K, C = w.shape
    lhs = xbc.transpose(0, 2, 1)  # [B, C, S]
    rhs = w.transpose(1, 0)[:, None, :]  # [C, 1, K]
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs.astype(lhs.dtype),
        window_strides=(1,),
        padding=[(K - 1, 0)],
        dimension_numbers=("NCH", "OIH", "NCH"),
        feature_group_count=C,
    )
    return out.transpose(0, 2, 1) + b.astype(xbc.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., Q] -> cumulative-segment-sum matrix [..., Q, Q] (i >= j)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_chunked(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]  (post-softplus)
    A: jax.Array,  # [H] negative
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    *,
    chunk: int = 256,
    h0: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    csh = lambda t, extra: t.reshape((Bsz, nc, chunk) + extra)
    xc = csh(x, (H, P))
    dtc = csh(dt, (H,))
    Bc = csh(Bm, (N,))
    Cc = csh(Cm, (N,))

    dA = dtc * A.astype(dtc.dtype)  # [B,c,Q,H]
    dA = dA.transpose(0, 1, 3, 2)  # [B,c,H,Q]
    dA_cs = jnp.cumsum(dA, -1)  # [B,c,H,Q]

    # --- intra-chunk (dense, MXU) ------------------------------------------
    L = jnp.exp(_segsum(dA.astype(jnp.float32)))  # [B,c,H,Q,Q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [B,c,Q,Q]
    scores = (
        scores[:, :, None] * L.astype(scores.dtype)
        * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    )  # [B,c,H,Q,Q]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # --- chunk states -------------------------------------------------------
    decay_states = jnp.exp(
        (dA_cs[..., -1:] - dA_cs).astype(jnp.float32)
    ).astype(x.dtype)  # [B,c,H,Q]
    states = jnp.einsum(
        "bcjn,bchj,bcjhp->bchpn", Bc, decay_states * dtc.transpose(0, 1, 3, 2), xc
    )  # [B,c,H,P,N]

    # --- inter-chunk recurrence --------------------------------------------
    chunk_decay = jnp.exp(dA_cs[..., -1].astype(jnp.float32)).astype(x.dtype)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), x.dtype)

    def body(h, inp):
        st, dec = inp  # [B,H,P,N], [B,H]
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit h_before

    (h_fin, h_befores) = jax.lax.scan(
        body,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_before = h_befores.transpose(1, 0, 2, 3, 4)  # [B,c,H,P,N]

    # --- off-diagonal output ------------------------------------------------
    state_decay = jnp.exp(dA_cs.astype(jnp.float32)).astype(x.dtype)  # [B,c,H,Q]
    y_off = jnp.einsum(
        "bcin,bchpn,bchi->bcihp", Cc, h_before, state_decay
    )
    y = (y_diag + y_off).reshape(Bsz, nc * chunk, H, P)[:, :S]
    return y, h_fin


def ssd_decode_step(
    x: jax.Array,  # [B, H, P]
    dt: jax.Array,  # [B, H]
    A: jax.Array,  # [H]
    Bm: jax.Array,  # [B, N]
    Cm: jax.Array,  # [B, N]
    h: jax.Array,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    dA = jnp.exp((dt * A.astype(dt.dtype)))  # [B,H]
    h_new = h * dA[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", x, Bm, dt
    )
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm)
    return y, h_new


def ssd_block_apply(
    p: Params,
    x: jax.Array,  # [B, S, E]
    *,
    d_inner: int,
    headdim: int,
    d_state: int,
    chunk: int = 256,
    cache: Optional[Dict[str, jax.Array]] = None,
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full mamba2 mixer. If ``cache`` is given, runs one decode step
    (S must be 1) and returns the updated cache."""
    H = d_inner // headdim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split(p, zxbcdt, d_inner, d_state, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is None:
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
        xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
        Bsz, S, _ = x.shape
        xh = xs.reshape(Bsz, S, H, headdim)
        dtp = jax.nn.softplus(
            dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        ).astype(x.dtype)
        y, _ = ssd_scan_chunked(
            xh, dtp, A.astype(x.dtype), Bm, Cm, chunk=chunk
        )
        y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh
        y = y.reshape(Bsz, S, d_inner)
        new_cache = None
    else:
        # decode: S == 1
        Bsz = x.shape[0]
        conv_state = cache["conv"]  # [B, K-1, conv_dim]
        win = jnp.concatenate([conv_state, xbc], axis=1)  # [B, K, conv_dim]
        conv_out = (
            jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(x.dtype))
            + p["conv_b"].astype(x.dtype)
        )[:, None, :]
        xbc = jax.nn.silu(conv_out)
        xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
        xh = xs.reshape(Bsz, H, headdim)
        dtp = jax.nn.softplus(
            dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        ).astype(x.dtype)
        y, h_new = ssd_decode_step(
            xh, dtp, A.astype(x.dtype), Bm[:, 0], Cm[:, 0], cache["ssm"]
        )
        y = y + p["D"].astype(x.dtype)[None, :, None] * xh
        y = y.reshape(Bsz, 1, d_inner)
        new_cache = {"conv": win[:, 1:], "ssm": h_new}

    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + norm_eps).astype(y.dtype)) * p["norm"].astype(
        y.dtype
    )
    out = y @ p["out_proj"].astype(x.dtype)
    return out, new_cache


def ssd_init_cache(
    batch: int, d_inner: int, headdim: int, d_state: int, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    H = d_inner // headdim
    conv_dim = d_inner + 2 * d_state
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, headdim, d_state), dtype),
    }
