"""Core layers: linear, norms, RoPE, GQA attention (train/prefill/decode).

Conventions
-----------
* params are dicts of arrays; every ``*_init`` returns ``(params, axes)``
  where ``axes`` mirrors params with logical-axis tuples.
* activations: ``x [B, S, E]``; attention heads ``[B, S, H, Dh]``.
* three attention modes:
    - ``dense``    : full scores (training, S <= ~4k; remat at layer level)
    - ``chunked``  : scan over KV blocks with online softmax (32k prefill)
    - ``decode``   : single-token query against a KV cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# inits
# ---------------------------------------------------------------------------
def linear_init(
    key,
    in_dim: int,
    out_dim: int,
    in_ax: Optional[str],
    out_ax: Optional[str],
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: Optional[float] = None,
) -> Tuple[Params, Params]:
    scale = (in_dim**-0.5) if scale is None else scale
    w = scale * jax.random.normal(key, (in_dim, out_dim), dtype)
    p, a = {"w": w}, {"w": (in_ax, out_ax)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (out_ax,)
    return p, a


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(dim: int, dtype=jnp.float32) -> Tuple[Params, Params]:
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Tuple[Params, Params]:
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (full-dim, or half-dim "2d" style as in ChatGLM)
# ---------------------------------------------------------------------------
def rope(
    x: jax.Array,  # [B, S, H, Dh]
    positions: jax.Array,  # [B, S] or [S]
    *,
    base: float = 10000.0,
    fraction: float = 1.0,
) -> jax.Array:
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------
def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: repeat kv heads to match query heads. k: [B, S, Kh, Dh]."""
    kh = k.shape[-2]
    if kh == n_heads:
        return k
    return jnp.repeat(k, n_heads // kh, axis=-2)


def _mask_bias(
    q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: Optional[int]
) -> jax.Array:
    """Additive bias [.., Sq, Sk] from causality / sliding window."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF)


def attention_dense(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, Kh, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    H, Dh = q.shape[-2], q.shape[-1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (Dh**0.5)
    q_pos = q_offset + jnp.arange(q.shape[1])
    k_pos = jnp.arange(k.shape[1])
    if causal or window is not None:
        scores = scores + _mask_bias(q_pos, k_pos, causal, window).astype(
            scores.dtype
        )
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax over KV blocks; O(S·block) live memory (prefill)."""
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    nblk = -(-Sk // block)
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, H, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, H, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)
    qs = q / (Dh**0.5)

    def body(carry, inp):
        m, l, acc = carry
        blk_idx, kblk, vblk = inp
        k_pos = blk_idx * block + jnp.arange(block)
        s = jnp.einsum("bqhd,bkhd->bhqk", qs, kblk)
        ok = k_pos[None, :] < Sk
        if causal:
            ok = ok & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(ok[None, None], s.astype(jnp.float32), NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nblk), kb, vb)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B, Sq, H, Dh]


def attention_decode(
    q: jax.Array,  # [B, 1, H, Dh]
    k_cache: jax.Array,  # [B, S, Kh, Dh]
    v_cache: jax.Array,
    cache_len: jax.Array,  # [] current filled length (the new token included)
    *,
    window: Optional[int] = None,
) -> jax.Array:
    H, Dh = q.shape[-2], q.shape[-1]
    k = _expand_kv(k_cache, H)
    v = _expand_kv(v_cache, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (Dh**0.5)
    k_pos = jnp.arange(k.shape[1])
    ok = k_pos < cache_len
    if window is not None:
        ok = ok & (k_pos >= cache_len - window)
    s = jnp.where(ok[None, None, None, :], s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def attn_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
    dtype=jnp.float32,
    pad_to: Optional[int] = None,
) -> Tuple[Params, Params]:
    """``pad_to``: §Perf head padding — create ``pad_to`` q-heads (and the
    proportional kv count) with ZERO-initialized extras (wq/wo rows), so
    the function is identical at init but head dims divide the TP axis."""
    n_real = n_heads
    if pad_to and pad_to > n_heads:
        ratio = max(1, n_heads // max(1, n_kv_heads))
        n_heads = pad_to
        n_kv_heads = max(1, pad_to // ratio)
    ks = jax.random.split(key, 4)
    s = d_model**-0.5
    p = {
        "wq": s * jax.random.normal(ks[0], (d_model, n_heads, head_dim), dtype),
        "wk": s * jax.random.normal(ks[1], (d_model, n_kv_heads, head_dim), dtype),
        "wv": s * jax.random.normal(ks[2], (d_model, n_kv_heads, head_dim), dtype),
        "wo": s * jax.random.normal(ks[3], (n_heads, head_dim, d_model), dtype),
    }
    if pad_to and n_heads > n_real:
        p["wq"] = p["wq"].at[:, n_real:, :].set(0.0)
        p["wo"] = p["wo"].at[n_real:, :, :].set(0.0)
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    return p, a


def attn_qkv(p: Params, x: jax.Array, xkv: Optional[jax.Array] = None):
    """Project to q, k, v. ``xkv`` (if given) is the cross-attention source."""
    src = x if xkv is None else xkv
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bse,ehd->bshd", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bse,ehd->bshd", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def attn_out(p: Params, ctx: jax.Array) -> jax.Array:
    return jnp.einsum("bshd,hde->bse", ctx, p["wo"].astype(ctx.dtype))


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GeLU)
# ---------------------------------------------------------------------------
def mlp_init(
    key, d_model: int, d_ff: int, *, act: str = "swiglu", dtype=jnp.float32
) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 3)
    s = d_model**-0.5
    so = d_ff**-0.5
    if act == "swiglu":
        p = {
            "wg": s * jax.random.normal(ks[0], (d_model, d_ff), dtype),
            "wu": s * jax.random.normal(ks[1], (d_model, d_ff), dtype),
            "wd": so * jax.random.normal(ks[2], (d_ff, d_model), dtype),
        }
        a = {
            "wg": ("embed", "mlp"),
            "wu": ("embed", "mlp"),
            "wd": ("mlp", "embed"),
        }
    else:
        p = {
            "wu": s * jax.random.normal(ks[0], (d_model, d_ff), dtype),
            "wd": so * jax.random.normal(ks[2], (d_ff, d_model), dtype),
        }
        a = {"wu": ("embed", "mlp"), "wd": ("mlp", "embed")}
    return p, a


def mlp(p: Params, x: jax.Array, *, act: str = "swiglu") -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (
            x @ p["wu"].astype(x.dtype)
        )
    else:
        h = jax.nn.gelu(x @ p["wu"].astype(x.dtype))
    return h @ p["wd"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------
def embed_init(
    key, vocab: int, d_model: int, *, dtype=jnp.float32, scale: float = 0.02
) -> Tuple[Params, Params]:
    e = scale * jax.random.normal(key, (vocab, d_model), dtype)
    return {"embedding": e}, {"embedding": ("vocab", "embed")}


def embed(p: Params, tokens: jax.Array, dtype=None) -> jax.Array:
    e = p["embedding"]
    if dtype is not None:
        e = e.astype(dtype)
    return jnp.take(e, tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bse,ve->bsv", x, p["embedding"].astype(x.dtype))


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple
