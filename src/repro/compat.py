"""Version compatibility helpers.

The codebase targets the current jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``); this module backfills the handful
of call sites that moved between jax 0.4.x and newer releases so the repo
runs on both. Import from here instead of feature-testing inline.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
        )

else:  # jax <= 0.4.x: experimental namespace, check_vma was check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_04(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=check_vma,
        )


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` without ``axis_types`` (absent in jax <= 0.4.x;
    explicit axis types are only needed by the newer sharding-in-types
    work, which this repo does not rely on)."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
