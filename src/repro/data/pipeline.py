"""Synthetic data pipelines.

* ``TokenPipeline`` — deterministic, shardable LM token stream with a
  learnable structure (Zipf-ish marginals + short-range induction pattern)
  so loss measurably decreases; per-step batches are a pure function of
  (seed, step) → identical resumption after checkpoint restore and
  identical batches per worker shard, as a real pipeline must guarantee.

* ``make_linreg`` — the paper's Sec. 5.1 Gaussian linear-model generator
  (per-worker ground truths t_n ~ N(u_n, h^2 I), u_n ~ N(U, sigma^2)),
  plus the analytic global optimum used for optimality-gap tracking.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    cfg: ModelConfig
    global_batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int | jax.Array) -> Dict[str, jax.Array]:
        """Pure function of step → batch (tokens, labels[, frontends])."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        ks = jax.random.split(key, 4)
        B, S, V = self.global_batch, self.seq, cfg.vocab
        # Zipf-ish marginal via squared-uniform index mapping
        u = jax.random.uniform(ks[0], (B, S))
        tokens = jnp.minimum((u * u * V).astype(jnp.int32), V - 1)
        # induction structure: with p=0.5 the label repeats a recent token
        flip = jax.random.bernoulli(ks[1], 0.5, (B, S))
        recent = jnp.roll(tokens, 3, axis=1)
        labels = jnp.where(flip, recent, jnp.roll(tokens, -1, axis=1))
        out = {"tokens": tokens, "labels": labels}
        if cfg.family == "encdec":
            out["frames"] = 0.1 * jax.random.normal(
                ks[2], (B, cfg.enc_seq, cfg.d_model), cfg.jdtype
            )
        if cfg.family == "vlm":
            out["patches"] = 0.1 * jax.random.normal(
                ks[3], (B, cfg.n_patches, cfg.vision_dim), cfg.jdtype
            )
        return out


class LinRegDataset(NamedTuple):
    X: jax.Array  # [N, Dn, J]
    y: jax.Array  # [N, Dn]
    theta_star: jax.Array  # [J]  analytic global optimum
    t_n: jax.Array  # [N, J] per-worker ground truths


def make_linreg(
    seed: int,
    n_workers: int = 20,
    dim: int = 100,
    n_points: int = 500,
    *,
    mean: float = 0.0,
    sigma2: float = 5.0,
    h2: float = 1.0,
    eps2: float = 0.5,
    homogeneous: bool = False,
) -> LinRegDataset:
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    if homogeneous:
        t0 = mean + jnp.sqrt(h2) * jax.random.normal(ks[1], (dim,))
        t_n = jnp.broadcast_to(t0, (n_workers, dim))
        eps2 = 0.0
    else:
        u_n = mean + jnp.sqrt(sigma2) * jax.random.normal(ks[0], (n_workers,))
        t_n = u_n[:, None] + jnp.sqrt(h2) * jax.random.normal(
            ks[1], (n_workers, dim)
        )
    X = jax.random.normal(ks[2], (n_workers, n_points, dim))
    e = jnp.sqrt(eps2) * jax.random.normal(ks[3], (n_workers, n_points))
    y = jnp.einsum("ndj,nj->nd", X, t_n) + e
    A = jnp.einsum("ndi,ndj->ij", X, X)
    b = jnp.einsum("ndj,nd->j", X, y)
    theta_star = jnp.linalg.solve(A, b)
    return LinRegDataset(X=X, y=y, theta_star=theta_star, t_n=t_n)


def linreg_grad_fn(data: LinRegDataset):
    """Returns grad_fn(theta, worker_idx) for the RSS loss (paper Eq. 48)."""
    Dn = data.X.shape[1]

    def grad_fn(theta, n):
        r = data.X[n] @ theta - data.y[n]
        return 2.0 / Dn * (data.X[n].T @ r)

    return grad_fn
