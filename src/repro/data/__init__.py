"""Deterministic synthetic data pipelines (offline container)."""
from repro.data.pipeline import LinRegDataset, TokenPipeline, make_linreg

__all__ = ["LinRegDataset", "TokenPipeline", "make_linreg"]
