"""Optimizers (pytree-native, distribution-aware state)."""
from repro.optim.optimizers import (
    OptConfig,
    adam,
    make_optimizer,
    sgd,
    sgd_momentum,
)

__all__ = ["OptConfig", "adam", "make_optimizer", "sgd", "sgd_momentum"]
