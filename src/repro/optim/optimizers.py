"""Minimal optimizer library (SGD / SGD-momentum / Adam).

Same functional shape as optax (init/update) but self-contained. Optimizer
state mirrors the parameter tree → it inherits the parameter sharding
(tensor-parallel dims sharded on "model", replicated across data-parallel),
which is exactly what the distributed trainer needs.

Distributed-Adam note (paper Sec. 5.3): workers run Adam on the *aggregated
sparsified* gradient, so moments stay identical across workers — the
update is computed once per replica from the common aggregate, matching
the paper's "distributed version of the Adam optimizer".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adam"  # sgd | momentum | adam
    learning_rate: float = 1e-3
    momentum: float = 0.9
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = None
    moment_dtype: str = "float32"  # "bfloat16" halves adam-state memory
    # simple schedule: linear warmup then constant (cosine optional)
    warmup_steps: int = 0


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def _lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    lr = jnp.asarray(cfg.learning_rate, jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
        lr = lr * warm
    return lr


def _clip(grads, max_norm: Optional[float]):
    if max_norm is None:
        return grads
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def sgd(cfg: OptConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads = _clip(grads, cfg.grad_clip)
        lr = _lr_at(cfg, state["step"])
        new_params = jax.tree.map(
            lambda p, g: p - (lr * g.astype(jnp.float32)).astype(p.dtype),
            params,
            grads,
        )
        return new_params, {"step": state["step"] + 1}

    return Optimizer(init, update)


def sgd_momentum(cfg: OptConfig) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        grads = _clip(grads, cfg.grad_clip)
        lr = _lr_at(cfg, state["step"])
        mom = jax.tree.map(
            lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
            state["mom"],
            grads,
        )
        new_params = jax.tree.map(
            lambda p, m: p - (lr * m).astype(p.dtype), params, mom
        )
        return new_params, {"step": state["step"] + 1, "mom": mom}

    return Optimizer(init, update)


def adam(cfg: OptConfig) -> Optimizer:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def init(params):
        z = lambda p: jnp.zeros_like(p, mdt)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
        }

    def update(grads, state, params):
        grads = _clip(grads, cfg.grad_clip)
        step = state["step"] + 1
        lr = _lr_at(cfg, state["step"])
        m = jax.tree.map(
            lambda m_, g: (
                cfg.b1 * m_.astype(jnp.float32)
                + (1 - cfg.b1) * g.astype(jnp.float32)
            ).astype(mdt),
            state["m"],
            grads,
        )
        v = jax.tree.map(
            lambda v_, g: (
                cfg.b2 * v_.astype(jnp.float32)
                + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32))
            ).astype(mdt),
            state["v"],
            grads,
        )
        bc1 = 1 - cfg.b1**step.astype(jnp.float32)
        bc2 = 1 - cfg.b2**step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            delta = lr * mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
            return p - delta.astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


_KINDS = {"sgd": sgd, "momentum": sgd_momentum, "adam": adam}


def make_optimizer(cfg: OptConfig) -> Optimizer:
    try:
        return _KINDS[cfg.kind](cfg)
    except KeyError:
        raise ValueError(
            f"unknown optimizer {cfg.kind!r}; available: {sorted(_KINDS)}"
        ) from None
