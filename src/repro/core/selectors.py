"""Top-k selection primitives.

All selectors operate on a 1-D non-negative ``score`` vector and return a
``{0,1}`` float mask (and optionally the selected values/indices as static
fixed-``k`` payloads, as required for TPU/XLA static shapes).

Two families:

* ``exact``      — ``jax.lax.top_k`` on the score (sort-bound, reference).
* ``threshold``  — iterative bisection for a threshold ``tau`` such that
  ``count(score >= tau) ~= k``; streaming / VPU-friendly, and the primitive
  that :mod:`repro.kernels.threshold_topk` implements as a Pallas kernel.
  The mask cardinality is approximately ``k`` (exactly ``k`` when there are
  no ties at ``tau`` and the bisection fully converges); callers that need a
  fixed-size payload combine it with :func:`fixed_k_payload`.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def exact_topk_mask(score: jax.Array, k: int) -> jax.Array:
    """Exact top-k mask via ``lax.top_k`` (ties broken by index order).

    A zero score carries no gradient and is never selected (the same
    contract the PR-2 fix gave :func:`threshold_topk_mask`), so the mask
    cardinality is ``min(k, #nonzero scores)`` — fewer than ``k`` only
    when the score vector itself has fewer than ``k`` live entries.

    >>> import jax.numpy as jnp
    >>> exact_topk_mask(jnp.array([0.1, 3.0, 0.2, 2.0]), 2).tolist()
    [0.0, 1.0, 0.0, 1.0]
    >>> exact_topk_mask(jnp.array([0.0, 3.0, 0.0, 0.0]), 2).tolist()
    [0.0, 1.0, 0.0, 0.0]
    """
    if score.ndim != 1:
        raise ValueError(f"score must be 1-D, got {score.shape}")
    k = int(k)
    if k <= 0:
        return jnp.zeros_like(score)
    if k >= score.shape[0]:
        return (score > 0).astype(score.dtype)
    _, idx = jax.lax.top_k(score, k)
    mask = jnp.zeros_like(score).at[idx].set(1.0)
    return mask * (score > 0)


def exact_topk_mask_dynamic(
    score: jax.Array, k: jax.Array, capacity: int
) -> jax.Array:
    """Exact top-k mask with a *traced* k under a static ``capacity``.

    The adaptive controller varies k per round inside one compiled step;
    XLA needs static shapes, so selection runs ``lax.top_k`` at the static
    upper bound ``capacity`` (the controller's ``k_max``) and the mask
    keeps only the first ``k`` (dynamic, ``k <= capacity``) of the
    descending-sorted winners. At ``k == capacity`` this is bit-for-bit
    :func:`exact_topk_mask` (same ``lax.top_k``, same zero-score
    exclusion) — the off-switch equivalence the differential tests pin.

    >>> import jax.numpy as jnp
    >>> s = jnp.array([0.1, 3.0, 0.2, 2.0])
    >>> exact_topk_mask_dynamic(s, jnp.asarray(1), 3).tolist()
    [0.0, 1.0, 0.0, 0.0]
    >>> exact_topk_mask_dynamic(s, jnp.asarray(3), 3).tolist()
    [0.0, 1.0, 1.0, 1.0]
    """
    if score.ndim != 1:
        raise ValueError(f"score must be 1-D, got {score.shape}")
    capacity = int(min(capacity, score.shape[0]))
    if capacity <= 0:
        return jnp.zeros_like(score)
    vals, idx = jax.lax.top_k(score, capacity)
    keep = (jnp.arange(capacity) < k) & (vals > 0)
    return jnp.zeros_like(score).at[idx].set(keep.astype(score.dtype))


def threshold_topk_mask(
    score: jax.Array, k: int, *, n_iters: int = 24
) -> jax.Array:
    """Approximate top-k mask via bisection on the selection threshold.

    Finds ``tau`` in ``[0, max(score)]`` such that ``sum(score >= tau)`` is
    the smallest count ``>= k``, using ``n_iters`` halvings. Cost is
    ``O(n_iters * J)`` elementwise work with no sort — the pattern the
    Pallas ``threshold_topk`` kernel accelerates with one histogram pass.

    >>> import jax.numpy as jnp
    >>> threshold_topk_mask(jnp.array([0.1, 3.0, 0.2, 2.0]), 2).tolist()
    [0.0, 1.0, 0.0, 1.0]
    """
    if score.ndim != 1:
        raise ValueError(f"score must be 1-D, got {score.shape}")
    k = int(k)
    if k <= 0:
        return jnp.zeros_like(score)
    if k >= score.shape[0]:
        return jnp.ones_like(score)

    hi0 = jnp.max(score)
    lo0 = jnp.zeros_like(hi0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(score >= mid)
        # keep the invariant count(lo) >= k
        lo, hi = jnp.where(count >= k, mid, lo), jnp.where(count >= k, hi, mid)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    # count(score >= lo) >= k; possibly > k on ties / unconverged bisection.
    # When the bisection collapses to tau = 0 (all-zero score, or fewer than
    # k positive entries) ``score >= 0`` would select *everything*; a zero
    # score carries no gradient, so exclude it — the mask cardinality stays
    # <= max(k, ties at tau) instead of blowing up to L.
    return ((score >= lo) & (score > 0)).astype(score.dtype)


def fixed_k_payload(
    score: jax.Array, values: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Return the fixed-size sparse payload ``(vals[k], idx[k])``.

    Selection is by ``score``; the payload carries ``values`` (which in
    RegTop-k differ from the score: the *accumulated gradient* is sent, the
    regularized score only ranks). Static ``k`` → static shapes for
    ``all_gather`` over the data-parallel axes.

    >>> import jax.numpy as jnp
    >>> score = jnp.array([0.1, 3.0, 0.2, 2.0])
    >>> vals, idx = fixed_k_payload(score, jnp.array([9., 8., 7., 6.]), 2)
    >>> vals.tolist(), idx.tolist()
    ([8.0, 6.0], [1, 3])
    """
    if score.ndim != 1:
        raise ValueError(f"score must be 1-D, got {score.shape}")
    k = int(k)
    _, idx = jax.lax.top_k(score, k)
    return values[idx], idx


def mask_to_payload(
    mask: jax.Array, values: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Convert a ~k-cardinality mask into an exactly-k payload.

    Ranks masked entries by |value| (unmasked entries rank -inf); if the
    mask has fewer than ``k`` entries the payload is padded with (0, 0)
    pairs, which are no-ops under scatter-add aggregation.

    >>> import jax.numpy as jnp
    >>> mask = jnp.array([0.0, 1.0, 0.0, 0.0])
    >>> vals, idx = mask_to_payload(mask, jnp.array([9., -8., 7., 6.]), 2)
    >>> vals.tolist(), idx.tolist()  # second slot is (0, 0) padding
    ([-8.0, 0.0], [1, 0])
    """
    ranked = jnp.where(mask > 0, jnp.abs(values), -jnp.inf)
    _, idx = jax.lax.top_k(ranked, int(k))
    vals = values[idx] * (mask[idx] > 0)
    idx = jnp.where(mask[idx] > 0, idx, 0)
    return vals, idx


SELECTORS = {
    "exact": exact_topk_mask,
    "threshold": threshold_topk_mask,
}


def get_selector(name: str):
    """Look up a selector family by name.

    >>> get_selector("exact") is exact_topk_mask
    True
    """
    try:
        return SELECTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown selector {name!r}; available: {sorted(SELECTORS)}"
        ) from None


def sparsity_to_k(length: int, sparsity: float) -> int:
    """Paper's S = k/J; returns k = ceil(S * J), clipped to [1, J].

    The ceil is epsilon-tolerant: ``S * J`` is computed in binary floating
    point, so nominally-integer products land a few ulps above the integer
    (``0.07 * 100 == 7.000000000000001``) and a naive ceil inflates k by one
    — inflating the compression ratio the paper defines as S = k/J.

    >>> sparsity_to_k(100, 0.07)
    7
    >>> sparsity_to_k(100, 0.071), sparsity_to_k(10, 0.0)
    (8, 1)
    """
    target = sparsity * length
    eps = 1e-9 * max(1.0, abs(target))
    k = math.ceil(target - eps)
    return max(1, min(length, k))
