"""Distributed training runtime: sparsified data-parallel x tensor-parallel.

The paper's communication pattern, mapped to a TPU mesh (DESIGN.md §2):

  1. per-worker local gradients — ``jax.vmap(value_and_grad)`` over a
     ``[W, ...]`` batch with params broadcast; the leading worker axis is
     sharded over the data-parallel mesh axes so each device holds exactly
     its own worker's (model-sharded) gradient. Optional microbatch
     accumulation (``lax.scan``) bounds activation memory.
  2. sparsify + aggregate — a fully-manual ``jax.shard_map`` over the whole
     mesh: each (worker, model-shard) runs the compact sparsifier on its
     flat local gradient shard, then the workers aggregate over the dp
     axes via either
       * ``dense_allreduce``  — psum of the sparse-but-dense vector
         (numerics-exact simulation / uncompressed baseline), or
       * ``sparse_allgather`` — all_gather of the fixed-k (value, index)
         payloads + local scatter-add: 2·N·k words instead of N·J on the
         wire — the paper's compression, with XLA-static shapes.
  3. optimizer update — pjit-auto, params/optimizer state sharded by the
     logical rules.

Per-(leaf x model-shard) top-k budgets (k = ceil(S * local_len)) follow
DGC/ScaleCom layer-wise practice; see DESIGN.md §Assumption-changes.

Wire formats and collectives are chosen *per leaf*: ``LeafPlan`` carries an
optional (codec, collective) pair, filled by the alpha–beta planner
(:mod:`repro.comm.autotune`) when ``DistConfig.codec`` / ``.collective`` is
``"auto"``, and falling back to the global ``DistConfig`` choice otherwise.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import comm
from repro.compat import shard_map
from repro.core import compact as C
from repro.core.selectors import sparsity_to_k
from repro.core.sparsify import SparsifierConfig
from repro.models.config import ModelConfig
from repro.nn import sharding as shlib
from repro.optim import OptConfig, make_optimizer

_DT = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class DistConfig:
    sparsifier: SparsifierConfig = SparsifierConfig(
        kind="regtopk", sparsity=0.001
    )
    optimizer: OptConfig = OptConfig(kind="adam", learning_rate=1e-4)
    aggregation: str = "sparse_allgather"  # legacy alias for ``collective``
    codec: str = "coo_fp32"  # repro.comm wire codec, or "auto" (per-leaf)
    collective: Optional[str] = None  # repro.comm strategy, "auto", or None
    microbatches: int = 1
    dp_axes: Tuple[str, ...] = ("data",)
    state_dtype: str = "float32"  # eps dtype ("bfloat16" for the big archs)
    rules: Optional[Dict[str, Optional[str]]] = None
    # alpha-beta link model driving codec/collective="auto" planning; None
    # uses comm.AlphaBeta() defaults (see comm.calibrate to fit one).
    link_model: Optional[comm.AlphaBeta] = None
    # per-dp-axis link topology (one AlphaBeta per axis in dp_axes order,
    # outermost/slowest first) — takes precedence over the scalar
    # link_model. Fit one with comm.calibrate_topo, or parse a CLI spec
    # with comm.parse_link_topo (train.py's --link-topo). A heterogeneous
    # topology is what makes collective="hierarchical" plannable: under a
    # uniform model it never strictly beats min(dense, allgather).
    link_topo: Optional[comm.LinkTopo] = None
    # partial-participation round schedule over the flat dp worker group
    # (comm.Participation; train.py's --participation). None (or a "full"
    # schedule) is the historical all-workers path, bit-for-bit. Dropping
    # schedules (bernoulli / round_robin) run in this shard_map runtime;
    # bounded-staleness ("stale") delivery needs the server-side pending
    # buffer and is simulator-only for now (DistributedSim).
    participation: Optional[comm.Participation] = None
    # fused select→encode fastpath (repro.comm.fastpath; train.py's
    # --fastpath): "off" (default) is the historical dense-selection path;
    # "on" routes every fusable leaf through the Pallas fused pipeline
    # (bit-for-bit equivalent — a runtime exactness certificate falls back
    # per call otherwise); "auto" fuses the leaves the measured-throughput
    # table prices faster, and resolves to "off" off-TPU where the kernels
    # run in interpret mode.
    fastpath: str = "off"
    # error-budget-driven per-round k (comm.AdaptiveKController; train.py's
    # --adaptive-k). None is the historical static-k path, bit-for-bit.
    # When set, every leaf's payload capacity is its k_max bound, the
    # controller's per-leaf k rides the round as a dynamic operand (no
    # retrace), and make_sparsify_aggregate threads a per-leaf
    # ControllerState tree alongside the sparsifier state.
    adaptive_k: Optional[comm.AdaptiveKController] = None
    # aggregation weighting axis ("worker" | "coordinate",
    # comm.collectives; train.py's --coord-weights). "coordinate"
    # renormalizes each coordinate by the mass of the workers that
    # actually sent it and records that mass in the compact state
    # (sent_w), which RegTop-k's posterior then conditions on; "worker"
    # is the historical per-worker Eq. (8) reduction, bit-for-bit.
    weighting: str = "worker"
    # bucketed overlap schedule ("off" | "buckets:B", comm.overlap;
    # train.py's --overlap). "buckets:B" splits the leaf tree into B
    # size-balanced launch buckets (greedy bin-pack on predicted per-axis
    # wire seconds) so each bucket's collective launches as soon as its
    # backward slice is done and hierarchical's slow inter-axis stage
    # pipelines behind the next bucket's intra-axis work. Numerics are
    # untouched (bucketing only reorders independent per-leaf rounds):
    # "off" and any B are bit-for-bit identical; what changes is the
    # predicted round timeline (comm_round_timeline, metrics["timeline"])
    # and the profiler annotation structure (jax.named_scope per bucket).
    overlap: str = "off"

    def resolved_collective(self) -> str:
        return self.collective or self.aggregation

    def resolved_weighting(self) -> str:
        """The effective weighting axis, with the config gates applied:
        kind='none' sends every coordinate (sender mass uniformly 1), so
        coordinate weighting would silently degenerate — reject it."""
        comm.check_weighting(self.weighting)
        if self.weighting == "coordinate" and self.sparsifier.kind == "none":
            raise ValueError(
                "weighting='coordinate' needs sparse payloads; kind='none' "
                "sends every coordinate, so the sender mass is uniformly 1 "
                "and coordinate weighting degenerates to the worker "
                "reduction — use weighting='worker'"
            )
        return self.weighting

    def resolved_fastpath(self) -> str:
        """The effective fastpath mode, with the environment gates applied:
        "auto" needs a TPU backend (interpret mode never wins), and the
        fused kernels score in f32 — a bf16 ``state_dtype`` scores in bf16
        on the unfused path, so fusing would not be bit-for-bit ("on"
        raises; "auto" declines)."""
        if self.fastpath not in comm.FASTPATH_MODES:
            raise ValueError(
                f"unknown fastpath {self.fastpath!r}; "
                f"available: {comm.FASTPATH_MODES}"
            )
        if self.fastpath == "off":
            return "off"
        if self.weighting == "coordinate" and self.sparsifier.kind == "regtopk":
            # the fused kernel scores with a *scalar* omega baked into the
            # pipeline; coordinate weighting scores with omega / sent_w.
            if self.fastpath == "on":
                raise ValueError(
                    "fastpath='on' cannot fuse regtopk under "
                    "weighting='coordinate': the fused score kernel bakes "
                    "a scalar omega, but coordinate weighting conditions "
                    "on the per-coordinate sender mass (sent_w) — use "
                    "fastpath='off'/'auto'"
                )
            return "off"
        if self.state_dtype != "float32":
            if self.fastpath == "on":
                raise ValueError(
                    "fastpath='on' requires state_dtype='float32': the "
                    "fused pipeline scores in f32 while the unfused path "
                    f"scores in {self.state_dtype} — selection would not "
                    "be bit-for-bit"
                )
            return "off"
        if self.fastpath == "auto" and not comm.fastpath.backend_supports():
            return "off"
        return self.fastpath

    def resolved_participation(self) -> Optional[comm.Participation]:
        """The active (non-full) schedule, or None when every round is
        full — callers skip participation logic entirely on None."""
        if self.participation is None or self.participation.is_full:
            return None
        return self.participation

    def resolved_link_model(self) -> comm.LinkModel:
        """The link model auto-planning scores with: the per-axis topology
        when given, else the scalar model, else comm.AlphaBeta() defaults."""
        if self.link_topo is not None:
            return self.link_topo
        return self.link_model or comm.AlphaBeta()

    def resolved_overlap(self) -> Optional[comm.OverlapConfig]:
        """The active bucketed-overlap config, or None when "off" —
        callers skip bucket scheduling entirely on None. The spec is
        validated here (unknown specs / non-positive bucket counts
        raise)."""
        return comm.parse_overlap(self.overlap)

    def resolved_adaptive_k(self) -> Optional[comm.AdaptiveKController]:
        """The active controller, with the config gates applied: adaptive
        k drives the magnitude-scored fixed-k kinds under the exact
        selector — anything else has no dynamic-k selection path."""
        if self.adaptive_k is None:
            return None
        if self.sparsifier.kind not in ("topk", "regtopk"):
            raise ValueError(
                "adaptive_k drives magnitude-scored fixed-k kinds "
                f"('topk'/'regtopk'); got {self.sparsifier.kind!r}"
            )
        if self.sparsifier.selector != "exact":
            raise ValueError(
                "adaptive_k requires selector='exact' (the capacity-"
                f"bounded lax.top_k path); got {self.sparsifier.selector!r}"
            )
        return self.adaptive_k


class LeafPlan(NamedTuple):
    global_shape: Tuple[int, ...]
    local_shape: Tuple[int, ...]
    local_len: int
    k: int
    spec: P
    # per-leaf wire choices; None defers to DistConfig's global setting.
    # build_plan(..., dist=...) fills them when codec/collective is "auto".
    codec: Optional[str] = None
    collective: Optional[str] = None
    # per-leaf fused select→encode flag; None defers to resolving
    # DistConfig.fastpath at aggregation-build time (leaf_fastpath).
    # build_plan(..., dist=...) fills it whenever fastpath != "off".
    fused: Optional[bool] = None


def _is_plan(x):
    return isinstance(x, LeafPlan)


def leaf_wire(p: LeafPlan, dist: DistConfig) -> Tuple[str, str]:
    """Resolve one leaf's (codec, collective): the leaf's own plan entry
    wins; otherwise the global DistConfig choice. "auto" must have been
    resolved at plan-build time (``build_plan(..., dist=...)``)."""
    codec = p.codec or dist.codec
    coll = p.collective or dist.resolved_collective()
    if codec == "auto" or coll == "auto":
        raise ValueError(
            "codec/collective='auto' requires a plan built with "
            "build_plan(..., dist=dist) so per-leaf choices are resolved"
        )
    return codec, coll


def leaf_fastpath(p: LeafPlan, dist: DistConfig) -> bool:
    """Resolve one leaf's fused select→encode flag: the plan's own entry
    wins (filled by ``build_plan(..., dist=...)``); otherwise the flag is
    derived here from ``dist.resolved_fastpath()`` and the fusability
    matrix — so plans built without ``dist`` still honor a fastpath set
    on the config afterwards."""
    mode = dist.resolved_fastpath()
    if mode == "off":
        return False
    if p.fused is not None:
        return p.fused
    if not comm.fastpath.config_fusable(dist.sparsifier)[0]:
        return False
    cname, coll = leaf_wire(p, dist)
    return comm.fastpath.leaf_fused(
        mode, cname, coll, p.local_len, p.k, scfg=dist.sparsifier
    )


def _local_shape(shape, spec: P, mesh) -> Tuple[int, ...]:
    out = []
    for dim, size in enumerate(shape):
        ax = spec[dim] if dim < len(spec) else None
        if ax is None:
            out.append(size)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        div = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(size // div)
    return tuple(out)


def build_plan(params_shape, specs, mesh, sparsity: float,
               dist: Optional[DistConfig] = None):
    """Per-leaf static sparsification plan.

    With ``dist`` given and ``dist.codec`` / ``dist.collective`` set to
    ``"auto"``, each leaf additionally gets a (codec, collective) pair
    picked by the alpha–beta planner (:mod:`repro.comm.autotune`) on the
    leaf's *local* shard length — tiny biases and dense-ish embedding
    shards end up on different wire formats. Fixed (non-"auto") choices
    leave the leaf fields ``None`` (global resolution via ``leaf_wire``).

    With ``dist.fastpath != "off"`` each leaf also gets its fused
    select→encode flag: under "auto" planning the planner prices the
    compute stage per candidate pair; under fixed wire choices the flag
    is the fusability matrix (+ throughput table for mode "auto") applied
    to the global (codec, collective).
    """
    from repro.comm import autotune, fastpath as fp_lib

    auto = dist is not None and (
        dist.codec == "auto" or (dist.collective or "") == "auto"
    )
    fp_mode = "off" if dist is None else dist.resolved_fastpath()
    if fp_mode != "off" and not fp_lib.config_fusable(dist.sparsifier)[0]:
        fp_mode = "off"
    if auto:
        dp_sizes = [mesh.shape[a] for a in dist.dp_axes]
        model = dist.resolved_link_model()
        word_bytes = jnp.dtype(_DT[dist.state_dtype]).itemsize
        participants = _dist_participants(dist, mesh)
        codecs = None if dist.codec == "auto" else [dist.codec]
        if dist.sparsifier.kind in ("none", "hard_threshold"):
            # no fixed-k payload exists: a *free* collective axis can only
            # resolve to the dense wire. An explicitly requested payload
            # collective is kept — downstream guards own that error.
            collectives = (
                ["dense_allreduce"] if dist.collective == "auto"
                else [dist.resolved_collective()]
            )
        else:
            collectives = (
                None if dist.collective == "auto"
                else [dist.resolved_collective()]
            )
        # a free codec axis stays lossless (auto must not change numerics);
        # an explicitly-fixed lossy codec is the user's call.
        allow_lossy = dist.codec != "auto"

    ctrl = None if dist is None else dist.resolved_adaptive_k()

    def mk(leaf, spec):
        ls = _local_shape(leaf.shape, spec, mesh)
        ll = int(np.prod(ls)) if ls else 1
        # adaptive leaves allocate (and get planned at) the controller's
        # k_max bound — the static payload capacity the rounds ship.
        k = sparsity_to_k(ll, sparsity) if ctrl is None else ctrl.bounds(ll)[1]
        if not auto:
            fused = None
            if fp_mode != "off":
                fused = fp_lib.leaf_fused(
                    fp_mode, dist.codec, dist.resolved_collective(), ll, k
                )
            return LeafPlan(
                tuple(leaf.shape), ls, ll, k, spec, fused=fused
            )
        d = autotune.choose_leaf(
            ll, k, dp_sizes, model,
            codecs=codecs, collectives=collectives,
            allow_lossy=allow_lossy, word_bytes=word_bytes,
            participants=participants, fastpath=fp_mode,
        )
        return LeafPlan(
            tuple(leaf.shape), ls, ll, k, spec, d.codec, d.collective,
            d.fused,
        )

    return jax.tree.map(mk, params_shape, specs)


def apply_plan_decisions(plan, comm_plan):
    """Graft a :class:`repro.comm.autotune.CommPlan`'s per-leaf (codec,
    collective, fused) decisions onto a ``LeafPlan`` tree — the bridge
    from ``comm.replan`` (measured-sample re-planning at runtime) back to
    the static plan ``make_sparsify_aggregate`` consumes. Capacities
    (``k``) are untouched, so sparsifier/controller state shapes survive
    the swap and training resumes without reinitialization. Accepts the
    ``CommPlan`` itself or its ``decisions`` tree."""
    decisions = getattr(comm_plan, "decisions", comm_plan)
    return jax.tree.map(
        lambda p, d: p._replace(
            codec=d.codec, collective=d.collective, fused=d.fused
        ),
        plan,
        decisions,
        is_leaf=_is_plan,
    )


# ---------------------------------------------------------------------------
# sparsifier state (compact, worker-major)
# ---------------------------------------------------------------------------
def sparsifier_state_shapes(plan, W: int, mesh, dp_axes, dtype):
    """(ShapeDtypeStruct state tree, PartitionSpec tree). Worker axis over
    dp; per-model-shard payload vectors carry an explicit shard axis."""
    M = mesh.shape["model"]
    dp = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]

    def mk_shape(p: LeafPlan):
        return C.CompactState(
            eps=jax.ShapeDtypeStruct((W,) + p.global_shape, dtype),
            sent_vals=jax.ShapeDtypeStruct((W, M, p.k), dtype),
            sent_g=jax.ShapeDtypeStruct((W, M, p.k), dtype),
            sent_idx=jax.ShapeDtypeStruct((W, M, p.k), jnp.int32),
            sent_w=jax.ShapeDtypeStruct((W, M, p.k), dtype),
            t=jax.ShapeDtypeStruct((W,), jnp.int32),
        )

    def mk_spec(p: LeafPlan):
        return C.CompactState(
            eps=P(dp, *tuple(p.spec)),
            sent_vals=P(dp, "model", None),
            sent_g=P(dp, "model", None),
            sent_idx=P(dp, "model", None),
            sent_w=P(dp, "model", None),
            t=P(dp),
        )

    shapes = jax.tree.map(mk_shape, plan, is_leaf=_is_plan)
    specs = jax.tree.map(mk_spec, plan, is_leaf=_is_plan)
    return shapes, specs


def init_sparsifier_state(plan, W: int, mesh, dp_axes, dtype, shardings=None):
    shapes, specs = sparsifier_state_shapes(plan, W, mesh, dp_axes, dtype)

    def mk(s, spec):
        if shardings is None:
            return jnp.zeros(s.shape, s.dtype)
        return jax.device_put(
            jnp.zeros(s.shape, s.dtype), NamedSharding(mesh, spec)
        )

    return jax.tree.map(mk, shapes, specs), specs


# ---------------------------------------------------------------------------
# adaptive-k controller state (per-leaf scalars, replicated)
# ---------------------------------------------------------------------------
def controller_state_specs(plan):
    """PartitionSpec tree for the per-leaf ``ControllerState`` scalars —
    replicated everywhere (each shard derives the identical update from
    psum'd norms, so replication is self-consistent)."""
    return jax.tree.map(
        lambda p: comm.ControllerState(P(), P(), P()), plan, is_leaf=_is_plan
    )


def init_controller_state(plan, dist: DistConfig):
    """(ControllerState tree mirroring ``plan``, PartitionSpec tree).

    Each leaf starts at the static-sparsity k clipped into the
    controller's per-leaf bounds; the plan must have been built with
    ``dist`` so leaf capacities already sit at ``k_max``."""
    ctrl = dist.resolved_adaptive_k()
    if ctrl is None:
        raise ValueError("init_controller_state needs dist.adaptive_k")

    def mk(p: LeafPlan):
        lo, hi = ctrl.bounds(p.local_len)
        return ctrl.init(
            sparsity_to_k(p.local_len, dist.sparsifier.sparsity), lo, hi
        )

    return (
        jax.tree.map(mk, plan, is_leaf=_is_plan),
        controller_state_specs(plan),
    )


def _ctrl_update(ctrl_cfg, ctrl_leaf, new_st, agg, p: LeafPlan, dp_axes,
                 model_axes, lo: int, hi: int):
    """Fold one leaf's round into its controller state (inside shard_map).

    Norms are assembled from the local shards: sum-of-squares psum'd over
    the non-dp (model) axes, then the per-worker eps norms pmean'd over
    dp. A leaf *replicated* over a model axis double-counts by the axis
    size — identically for eps and g_agg, so the ratio the budget
    regulates is unaffected."""
    eps = new_st.eps[0].reshape(p.local_len).astype(jnp.float32)
    eps_sq = jnp.sum(eps * eps)
    ag = agg.reshape(p.local_len).astype(jnp.float32)
    g_sq = jnp.sum(ag * ag)
    if model_axes:
        eps_sq = jax.lax.psum(eps_sq, model_axes)
        g_sq = jax.lax.psum(g_sq, model_axes)
    eps_norm = jax.lax.pmean(jnp.sqrt(eps_sq), dp_axes)
    return ctrl_cfg.observe(
        ctrl_leaf, eps_norm, jnp.sqrt(g_sq), k_min=lo, k_max=hi
    )


# ---------------------------------------------------------------------------
# the sparsify+aggregate shard_map stage
# ---------------------------------------------------------------------------
def _spa_leaf(g, st, p: LeafPlan, scfg, codec, collective, dp_axes,
              part_ctx=None, fused=False, k_dyn=None, weighting="worker"):
    """Local (worker x model-shard) view: g [1, *local], st with leading
    [1(,1)] axes. Returns (agg local shard [*local], new state).

    All aggregation routes through :mod:`repro.comm`: the ``dense_allreduce``
    strategy psums the sparse-but-dense vector (uncompressed, exact); payload
    strategies encode the fixed-k payload with ``codec``, run the collective,
    and error-feed back against the *decoded* contribution so lossy codecs
    (``coo_q8``) keep their residual in ``eps``.

    ``fused`` routes selection through the Pallas fused select→encode
    pipeline (``compact_select(..., fastpath="on")`` +
    ``codec.encode_fused`` — no dense score/mask/masked-gradient
    intermediates, bit-for-bit equivalent) — callers only set it on
    leaves the fusability matrix admits (see ``leaf_fastpath``).

    ``part_ctx`` (``(m, w_part)``, computed once per round by
    ``make_sparsify_aggregate`` from the shared schedule) makes the round
    partial: ``m`` is this worker's ``{0,1}`` mask entry and ``w_part``
    the renormalized participant weight ``1/|P_t|``. Participants
    aggregate with ``w_part``; a dropped worker keeps its whole
    accumulated gradient in ``eps`` with its posterior statistics
    (``sent_*``) frozen at the last round the server actually saw it —
    error feedback covers non-participation exactly like sparsification.
    ``part_ctx=None`` is the historical full round, bit-for-bit.

    ``k_dyn`` (traced int, adaptive-k rounds only) caps the effective
    payload cardinality below the static capacity ``p.k`` — see
    ``compact_select``; ``None`` is the historical static-k selection.

    ``weighting="coordinate"`` renormalizes each coordinate by the sender
    mass of the workers that actually sent it (``shard_coord`` /
    presence-psum) and records that mass at the sent coords in the state's
    ``sent_w``, which the next round's RegTop-k posterior conditions on;
    ``"worker"`` records 1.0 there and is bit-for-bit the historical path.
    """
    gl = g[0].reshape(p.local_len)
    stl = C.CompactState(
        eps=st.eps[0].reshape(p.local_len),
        sent_vals=st.sent_vals[0, 0],
        sent_g=st.sent_g[0, 0],
        sent_idx=st.sent_idx[0, 0],
        sent_w=st.sent_w[0, 0],
        t=st.t[0],
    )
    if part_ctx is not None:
        m, w_part = part_ctx
    if scfg.kind == "none":
        if part_ctx is None:
            agg = jax.lax.pmean(
                gl.astype(jnp.float32), dp_axes
            ).astype(gl.dtype)
        else:
            # no error state: a dropped worker's gradient is simply lost
            agg = jax.lax.psum(
                gl.astype(jnp.float32) * (m * w_part), dp_axes
            ).astype(gl.dtype)
        new = stl._replace(t=stl.t + 1)
    else:
        a, vals, idx = C.compact_select(
            scfg, stl, gl, p.k, k_dyn=k_dyn,
            fastpath="on" if fused else None,
        )
        omega = scfg.omega if part_ctx is None else w_part
        shard_mask = None if part_ctx is None else m
        coord = weighting == "coordinate"
        den = None  # per-coordinate sender mass (coordinate weighting)
        if collective == "dense_allreduce":
            # scatter-ADD: payload padding (value 0 on a real or duplicate
            # index) must be a no-op, never overwrite a live contribution
            ghat = jnp.zeros_like(a).at[idx].add(vals)
            w = omega if part_ctx is None else omega * m
            if coord:
                # presence from the dense contribution (mirrors
                # DenseAllreduce.shard_coord): padding slots carry value 0
                # and contribute no sender mass.
                presence = (ghat != 0).astype(ghat.dtype)
                num = jax.lax.psum(ghat * w, dp_axes)
                den = jax.lax.psum(presence * w, dp_axes)
                agg = num / jnp.maximum(den, jnp.finfo(den.dtype).tiny)
            else:
                agg = jax.lax.psum(ghat * w, dp_axes)
            new = C.compact_finalize(stl, a, vals, idx, agg, den=den)
        else:
            payload = (
                codec.encode_fused(vals, idx, p.local_len)
                if fused
                else codec.encode(vals, idx, p.local_len)
            )
            dvals, didx = codec.decode(payload, p.local_len)
            sent_dense = (
                jnp.zeros_like(a).at[didx].add(dvals.astype(a.dtype))
            )
            strategy = comm.get_collective(collective)
            if coord:
                agg, den = strategy.shard_coord(
                    codec, payload, p.local_len, dp_axes, omega,
                    participation=shard_mask,
                )
                agg = agg.astype(a.dtype)
                den = den.astype(a.dtype)
            else:
                agg = strategy.shard(
                    codec, payload, p.local_len, dp_axes, omega,
                    participation=shard_mask,
                ).astype(a.dtype)
            new = C.compact_finalize_sent(
                stl, a, dvals, didx, sent_dense, agg, den=den
            )
        if part_ctx is not None:
            dropped = C.CompactState(
                eps=a,
                sent_vals=stl.sent_vals,
                sent_g=stl.sent_g,
                sent_idx=stl.sent_idx,
                sent_w=stl.sent_w,
                t=stl.t + 1,
            )
            new = jax.tree.map(
                lambda live, gone: jnp.where(m > 0, live, gone), new, dropped
            )
    new_out = C.CompactState(
        eps=new.eps.reshape((1,) + p.local_shape),
        sent_vals=new.sent_vals[None, None],
        sent_g=new.sent_g[None, None],
        sent_idx=new.sent_idx[None, None],
        sent_w=new.sent_w[None, None],
        t=new.t[None],
    )
    return agg.reshape(p.local_shape).astype(g.dtype), new_out


def make_sparsify_aggregate(
    mesh, plan, param_specs, state_specs, dist: DistConfig, n_workers: int
):
    dp = tuple(dist.dp_axes)
    dp_spec = dp if len(dp) > 1 else dp[0]
    dp_sizes = tuple(int(mesh.shape[a]) for a in dp)
    part = dist.resolved_participation()
    if part is not None:
        part.validate(n_workers)
        if part.delays_payloads:
            raise ValueError(
                "participation kind 'stale' (bounded-staleness delivery) "
                "needs the server-side pending buffer and is simulator-only "
                "for now — use DistributedSim(participation=...), or a "
                "dropping schedule ('bernoulli'/'round_robin') here"
            )
    # RegTop-k's posterior distortion subtracts this worker's own
    # contribution omega*a_prev from the broadcast; under a partial
    # schedule the server aggregated it with the schedule's effective
    # weight (renormalized 1/|P_t| — exact for fixed-size schedules,
    # expected for bernoulli; 1/S for client sampling), so that is the
    # omega the posterior must condition on. Under coordinate weighting
    # this is the *base* per-worker mass; the per-coordinate divisor
    # rides the state as sent_w.
    omega = (
        1.0 / n_workers
        if part is None
        else part.effective_omega(n_workers)
    )
    scfg = dataclasses.replace(dist.sparsifier, omega=omega)
    weighting = dist.resolved_weighting()
    plan_flat, plan_def = jax.tree.flatten(plan, is_leaf=_is_plan)
    # per-leaf wire choices (one global pair when the plan carries none);
    # resolve + validate every distinct pair up front — fail fast.
    wires = [leaf_wire(p, dist) for p in plan_flat]
    for cname, sname in set(wires):
        comm.get_codec(cname)
        comm.get_collective(sname)
    leaf_codecs = [comm.get_codec(c) for c, _ in wires]
    # per-leaf fused select→encode flags; a fused leaf must actually be
    # fusable end to end (a stale plan flag on a non-fusable wire would
    # call a missing encode_fused deep inside shard_map — fail fast here).
    fused_flags = [leaf_fastpath(p, dist) for p in plan_flat]
    for p, (cname, sname), fval in zip(plan_flat, wires, fused_flags, strict=True):
        if not fval:
            continue
        ok, why = comm.fusable(
            dist.sparsifier, cname, sname, p.local_len, p.k
        )
        if not ok:
            raise ValueError(
                f"plan marks a {p.local_len}-element leaf fused but the "
                f"({cname}, {sname}) pair is not fusable: {why}"
            )

    ctrl_cfg = dist.resolved_adaptive_k()
    if ctrl_cfg is not None:
        model_axes = tuple(a for a in mesh.axis_names if a not in dp)
        leaf_bounds = [ctrl_cfg.bounds(p.local_len) for p in plan_flat]
        for p, (_, hi) in zip(plan_flat, leaf_bounds, strict=True):
            if p.k != hi:
                raise ValueError(
                    f"adaptive-k plan capacity mismatch: a {p.local_len}-"
                    f"element leaf carries k={p.k} but the controller's "
                    f"k_max bound is {hi} — build the plan with "
                    "build_plan(..., dist=dist) so capacities sit at k_max"
                )

    # bucketed overlap: precompute the leaf launch order (and the profiler
    # scope names) at trace time. Off keeps the flat single-group order —
    # the historical program, bit-for-bit; buckets only *reorder* the
    # independent per-leaf rounds and annotate them with jax.named_scope,
    # so the math is identical either way.
    ocfg = dist.resolved_overlap()
    bucket_order: List[Tuple[int, ...]] = [tuple(range(len(plan_flat)))]
    bucket_scopes: List[Optional[str]] = [None]
    if ocfg is not None and plan_flat:
        bplan = comm.bucketize(_leaf_overlap_costs(plan, dist, mesh), ocfg)
        bucket_order = [b.leaves for b in bplan.buckets]
        bucket_scopes = [
            f"spa_bucket{i:03d}" for i in range(len(bucket_order))
        ]

    def rounds(grads, state, ctrl=None):
        g_flat = plan_def.flatten_up_to(grads)
        s_flat = plan_def.flatten_up_to(state)
        part_ctx = None
        if part is not None:
            # one mask per round, shared by every leaf (all leaf round
            # counters advance in lockstep): this worker's mask entry and
            # the common renormalized participant weight 1/|P_t| (the
            # runtime's omega is uniform, so w*m/sum(w*m) reduces to it).
            pmask = part.round_mask(s_flat[0].t[0], n_workers)
            m = pmask[comm.worker_index(dp, dp_sizes)]
            part_ctx = (m, 1.0 / jnp.maximum(pmask.sum(), 1.0))
        c_flat = (
            plan_def.flatten_up_to(ctrl) if ctrl is not None
            else [None] * len(plan_flat)
        )
        outs: List = [None] * len(plan_flat)
        for scope, leaves in zip(bucket_scopes, bucket_order, strict=True):
            ctx = (
                jax.named_scope(scope) if scope
                else contextlib.nullcontext()
            )
            with ctx:
                for i in leaves:
                    c = c_flat[i]
                    outs[i] = _spa_leaf(
                        g_flat[i], s_flat[i], plan_flat[i], scfg,
                        leaf_codecs[i], wires[i][1], dp, part_ctx,
                        fused_flags[i],
                        k_dyn=None if c is None else c.k,
                        weighting=weighting,
                    )
        agg = jax.tree.unflatten(plan_def, [o[0] for o in outs])
        new_state = jax.tree.unflatten(plan_def, [o[1] for o in outs])
        if ctrl is None:
            return agg, new_state
        new_ctrl = jax.tree.unflatten(plan_def, [
            _ctrl_update(
                ctrl_cfg, c, o[1], o[0], p, dp, model_axes, lo, hi
            )
            for o, c, p, (lo, hi) in zip(
                outs, c_flat, plan_flat, leaf_bounds, strict=True
            )
        ])
        return agg, new_state, new_ctrl

    grads_in_specs = jax.tree.map(lambda s: P(dp_spec, *tuple(s)), param_specs)
    if ctrl_cfg is None:
        return shard_map(
            lambda grads, state: rounds(grads, state),
            mesh=mesh,
            in_specs=(grads_in_specs, state_specs),
            out_specs=(param_specs, state_specs),
            check_vma=False,
        )
    ctrl_specs = controller_state_specs(plan)
    return shard_map(
        rounds,
        mesh=mesh,
        in_specs=(grads_in_specs, state_specs, ctrl_specs),
        out_specs=(param_specs, state_specs, ctrl_specs),
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# communication accounting (repro.comm.cost over the per-leaf plan)
# ---------------------------------------------------------------------------
def _dist_participants(dist: DistConfig, mesh) -> Optional[float]:
    """Expected on-time workers per round under ``dist.participation`` —
    what partial-round cost accounting and auto-planning price with; None
    when every round is full."""
    part = dist.resolved_participation()
    if part is None:
        return None
    W = int(np.prod([mesh.shape[a] for a in dist.dp_axes]))
    return part.validate(W).expected_participants(W)


def _leaf_wire_patterns(plan, dist: DistConfig):
    """Yield ``(leaf, codec, effective_collective, word_bytes, dense_wire)``
    with the word-sizing rules shared by byte and cost accounting: the
    sparsified dense psum carries the state-dtype vector (bf16 halves it),
    the kind="none" pmean upcasts to f32 first (see ``_spa_leaf``), and
    payload strategies decode to f32 before any intra-axis psum
    (hierarchical), so their dense terms stay 4-byte words."""
    dense_word = (
        4
        if dist.sparsifier.kind == "none"
        else jnp.dtype(_DT[dist.state_dtype]).itemsize
    )
    for p in jax.tree.leaves(plan, is_leaf=_is_plan):
        cname, collective = leaf_wire(p, dist)
        dense_wire = dist.sparsifier.kind == "none" or (
            collective == "dense_allreduce"
        )
        yield (
            p,
            comm.get_codec(cname),
            "dense_allreduce" if dense_wire else collective,
            dense_word if dense_wire else comm.cost.WORD_BYTES,
            dense_wire,
        )


def comm_round_bytes(plan, dist: DistConfig, mesh) -> Tuple[int, int]:
    """(predicted, measured) bytes-on-wire per worker per round, summed over
    leaves — each with its *own* (codec, collective) when the plan carries
    per-leaf choices. Predicted comes from the codec's bit accounting;
    measured from the actual encoded buffer shapes (via ``jax.eval_shape``
    — exact, since payload shapes are static).

    Under a partial-participation schedule the *predicted* side prices the
    idealized partial round (only participants' payloads move — what a
    straggler-aware transport would ship), while the *measured* side stays
    the full round: the SPMD runtime still gathers every worker's
    (zero-masked) full-size buffer, so that is what actually crosses the
    wire. ``measured - predicted`` is the transport headroom a
    sparse-membership collective would recover."""
    dp_sizes = [mesh.shape[a] for a in dist.dp_axes]
    participants = _dist_participants(dist, mesh)
    pred = meas = 0
    for p, codec, coll, wb, dense_wire in _leaf_wire_patterns(plan, dist):
        pred += comm.predicted_bytes(
            codec, coll, p.local_len, p.k, dp_sizes, word_bytes=wb,
            participants=participants,
        )
        payload_shape = {} if dense_wire else jax.eval_shape(
            lambda v, i, c=codec, L=p.local_len: c.encode(v, i, L),
            jax.ShapeDtypeStruct((p.k,), jnp.float32),
            jax.ShapeDtypeStruct((p.k,), jnp.int32),
        )
        meas += comm.measured_bytes(
            coll, p.local_len, payload_shape, dp_sizes, word_bytes=wb
        )
    return pred, meas


def comm_round_cost(plan, dist: DistConfig, mesh) -> comm.CostEstimate:
    """Predicted per-worker alpha–beta cost of one full round, summed over
    leaves under ``dist``'s resolved link model — the per-axis
    :class:`~repro.comm.cost.LinkTopo` when configured, so a slow outer
    axis shows up in the round seconds exactly as the planner scored it.
    Word sizing is shared with :func:`comm_round_bytes` via
    ``_leaf_wire_patterns``; a partial-participation schedule prices the
    expected partial round (strictly cheaper than full on any charged
    axis with more than one worker)."""
    dp_sizes = [mesh.shape[a] for a in dist.dp_axes]
    model = dist.resolved_link_model()
    participants = _dist_participants(dist, mesh)
    total_bytes = total_msgs = 0
    total_seconds = 0.0
    for p, codec, coll, wb, _ in _leaf_wire_patterns(plan, dist):
        est = comm.predict(
            codec, coll, p.local_len, p.k, dp_sizes, model, word_bytes=wb,
            participants=participants,
        )
        total_bytes += est.bytes_on_wire
        total_msgs += est.n_messages
        total_seconds += est.seconds
    return comm.CostEstimate(
        bytes_on_wire=total_bytes,
        n_messages=total_msgs,
        seconds=total_seconds,
    )


def _leaf_overlap_costs(plan, dist: DistConfig, mesh):
    """Per-leaf :class:`repro.comm.LeafCost` rows (bytes + per-axis stage
    seconds) in flat plan order, under ``dist``'s resolved link model —
    the :func:`repro.comm.bucketize` input. Word sizing and collective
    resolution are shared with byte/cost accounting via
    ``_leaf_wire_patterns``, so the bucket schedule prices exactly the
    wire the round runs."""
    dp_sizes = [mesh.shape[a] for a in dist.dp_axes]
    model = dist.resolved_link_model()
    participants = _dist_participants(dist, mesh)
    return [
        comm.leaf_cost(
            codec, coll, p.local_len, p.k, dp_sizes, model,
            word_bytes=wb, participants=participants,
        )
        for p, codec, coll, wb, _ in _leaf_wire_patterns(plan, dist)
    ]


def comm_round_timeline(
    plan, dist: DistConfig, mesh, compute_seconds=None
) -> Tuple[comm.BucketPlan, comm.Timeline]:
    """The bucket schedule and predicted overlapped timeline of one round
    under ``dist.resolved_overlap()`` (raises when overlap is "off" —
    there is no schedule to report). ``compute_seconds`` optionally
    threads per-bucket backward-slice times into the launch stamps;
    ``timeline.sync_seconds`` matches :func:`comm_round_cost`'s
    ``seconds`` to fp summation order, and ``timeline.seconds`` never
    exceeds it."""
    ocfg = dist.resolved_overlap()
    if ocfg is None:
        raise ValueError(
            "comm_round_timeline needs DistConfig.overlap != 'off' "
            "(e.g. overlap='buckets:4')"
        )
    bplan = comm.bucketize(_leaf_overlap_costs(plan, dist, mesh), ocfg)
    return bplan, comm.overlap_timeline(bplan, compute_seconds)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def make_train_step(
    model_mod,
    cfg: ModelConfig,
    dist: DistConfig,
    mesh,
    param_specs,
    plan,
    state_specs,
):
    """train_step(params, opt_state, sp_state, batch) ->
    (params, opt_state, sp_state, metrics)

    With ``dist.adaptive_k`` set, ``sp_state`` is the *pair*
    ``(compact_state_tree, controller_state_tree)`` (see
    :func:`init_controller_state`) and metrics gain ``"adaptive_k"``, the
    mean effective per-leaf k the round just used."""
    opt = make_optimizer(dist.optimizer)
    adaptive = dist.resolved_adaptive_k() is not None
    W = int(np.prod([mesh.shape[a] for a in dist.dp_axes]))
    spa = make_sparsify_aggregate(
        mesh, plan, param_specs, state_specs, dist, W
    )
    n_mb = dist.microbatches
    dp_spec = (
        tuple(dist.dp_axes) if len(dist.dp_axes) > 1 else dist.dp_axes[0]
    )
    wire_pred, wire_meas = comm_round_bytes(plan, dist, mesh)
    # bucketed overlap instrumentation: the per-bucket (launch, complete)
    # stamps of the predicted round timeline, surfaced every step as
    # metrics["timeline"] [n_buckets, 2] alongside the jax.named_scope
    # annotations the aggregation emits per bucket (profiler-visible —
    # jax.profiler traces group the collectives under spa_bucketNNN).
    timeline_stamps = None
    if dist.resolved_overlap() is not None:
        _, tl = comm_round_timeline(plan, dist, mesh)
        timeline_stamps = np.stack(
            [
                np.asarray(tl.launch, np.float32),
                np.asarray(tl.complete, np.float32),
            ],
            axis=1,
        )

    acc_dt = _DT[dist.state_dtype]

    def worker_grads(params, wbatch):
        def gfn(mb):
            return jax.value_and_grad(
                lambda p: model_mod.loss_fn(p, cfg, mb)[0]
            )(params)

        if n_mb == 1:
            return gfn(wbatch)
        mbatch = jax.tree.map(
            lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
            wbatch,
        )

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = gfn(mb)
            return (
                loss_acc + loss / n_mb,
                jax.tree.map(
                    lambda ac, gg: ac + (gg / n_mb).astype(acc_dt), g_acc, g
                ),
            ), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros(()), zero), mbatch
        )
        return loss, grads

    def train_step(params, opt_state, sp_state, batch):
        wb = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape((W, x.shape[0] // W) + x.shape[1:]),
                NamedSharding(mesh, P(dp_spec)),
            ),
            batch,
        )
        losses, grads_w = jax.vmap(worker_grads, in_axes=(None, 0))(params, wb)
        grads_w = jax.tree.map(
            lambda g: g.astype(_DT[dist.state_dtype]), grads_w
        )
        if adaptive:
            cp_state, ctrl_state = sp_state
            agg, new_cp, new_ctrl = spa(grads_w, cp_state, ctrl_state)
            new_sp = (new_cp, new_ctrl)
        else:
            agg, new_sp = spa(grads_w, sp_state)
        new_params, new_opt = opt.update(agg, opt_state, params)
        metrics = {
            "loss": losses.mean(),
            "comm_bytes": jnp.asarray(wire_meas, jnp.float32),
            "comm_bytes_predicted": jnp.asarray(wire_pred, jnp.float32),
        }
        if timeline_stamps is not None:
            metrics["timeline"] = jnp.asarray(timeline_stamps)
        if adaptive:
            # the k each leaf *used* this round (ctrl carries next round's)
            ks = [
                c.k for c in jax.tree.leaves(
                    ctrl_state,
                    is_leaf=lambda x: isinstance(x, comm.ControllerState),
                )
            ]
            metrics["adaptive_k"] = (
                jnp.stack([jnp.asarray(k, jnp.float32) for k in ks]).mean()
            )
        return new_params, new_opt, new_sp, metrics

    return train_step


# ---------------------------------------------------------------------------
# assembly (shapes only — safe for dry runs; allocation helpers for tests)
# ---------------------------------------------------------------------------
class Assembled(NamedTuple):
    train_step: Callable
    params_shape: Any
    axes: Any
    param_specs: Any
    state_shapes: Any
    state_specs: Any
    plan: Any


def shapes_and_axes(model_mod, cfg: ModelConfig):
    """Abstract init: parameter ShapeDtypeStructs + logical axes, no
    allocation (axes captured through a side cell during tracing)."""
    cell = {}

    def f():
        p, a = model_mod.init(jax.random.PRNGKey(0), cfg)
        cell["axes"] = a
        return p

    shapes = jax.eval_shape(f)
    return shapes, cell["axes"]


def assemble(model_mod, cfg: ModelConfig, dist: DistConfig, mesh) -> Assembled:
    params_shape, axes = shapes_and_axes(model_mod, cfg)
    param_specs = shlib.tree_specs(
        params_shape, axes, mesh, rules=dist.rules, dp_axes=dist.dp_axes
    )
    plan = build_plan(
        params_shape, param_specs, mesh, dist.sparsifier.sparsity, dist
    )
    W = int(np.prod([mesh.shape[a] for a in dist.dp_axes]))
    state_shapes, state_specs = sparsifier_state_shapes(
        plan, W, mesh, dist.dp_axes, _DT[dist.state_dtype]
    )
    step = make_train_step(
        model_mod, cfg, dist, mesh, param_specs, plan, state_specs
    )
    return Assembled(
        step, params_shape, axes, param_specs, state_shapes, state_specs, plan
    )
