"""Gradient sparsification algorithms (the paper's core contribution).

Implements, over flat 1-D gradient vectors:

* ``NoneSparsifier``      — identity (the paper's "no sparsification" line).
* ``TopK``                — Algorithm 1 (error accumulation + magnitude top-k).
* ``RegTopK``             — Algorithm 2, the paper's contribution: Bayesian
  MAP selection with the Top-k prior and the asymptotic likelihood
  ``u_mu(|1 + Delta|)``; selection metric
  ``|a|^y * tanh(|1 + Delta| / mu)`` with unsent coordinates assigned
  distortion ``Q -> inf`` (regularizer ``C = tanh(Q) = 1``).
* ``HardThreshold``       — the total-error-minimizing baseline of
  Sahu et al., NeurIPS'21 [27]: ``mask = |a| >= lam`` (variable k).

All sparsifiers share one functional interface::

    state            = sparsifier.init(length)                 # per worker
    ghat, sel, state = sparsifier.step(state, g_local, g_agg_prev)
    # ... server aggregates ghat across workers into g_agg ...

``g_agg_prev`` is the previous round's *aggregated* gradient (known to all
workers — it is what the server broadcast), required by RegTop-k's posterior
distortion. Error accumulation, mask memory and step count live in
``state`` (a pytree of arrays → shardable, checkpointable, vmappable over a
leading worker axis).

The optional ``omega_prev`` argument to ``step``/``step_dyn`` is the
previous round's per-coordinate sender mass ``den[j]`` under
``weighting="coordinate"`` aggregation (:mod:`repro.comm.collectives`):
the server divided coordinate ``j`` by ``den[j]``, so this worker's
effective weight there was ``omega / den[j]`` — RegTop-k's posterior must
subtract its own contribution with that weight, not the scalar ``omega``.
``None`` (the default) is the scalar worker-weighting path, bit-for-bit.

Every mutation of ``SparsifierState`` slots lives *here*, behind the
``Sparsifier`` interface — including the two runtime hooks:

* ``on_wire_residual(state, delta)`` — a lossy codec transmitted
  ``intended + delta``; fold the residual into error feedback (and, for
  RegTop-k, into the posterior's ``a_prev`` so Line 8 conditions on what
  the server actually decoded).
* ``on_dropped(old_state, new_state, ghat)`` — the worker's payload was
  dropped by a partial-participation round. Slot semantics are
  kind-specific (DGC keeps its momentum buffer where RegTop-k keeps
  ``a_prev``; CoordTopK keeps a *common* staleness counter there), so the
  rewrite must be owned by the kind — reprolint rule RPL106 flags slot
  writes anywhere else.

The math follows the paper exactly; see each class's docstring for the
equation mapping.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import selectors as sel_lib


class SparsifierState(NamedTuple):
    """Per-worker persistent state (all shapes ``[L]`` except ``t``).

    eps     — sparsification error  (paper's eps_n^t);   zeros for stateless.
    a_prev  — previous accumulated gradient a_n^{t-1}    (RegTop-k only).
    s_prev  — previous mask s_n^{t-1} in {0,1}           (RegTop-k only).
    t       — round counter; t == 0 applies plain Top-k (Alg. 2 line 2).
    """

    eps: jax.Array
    a_prev: jax.Array
    s_prev: jax.Array
    t: jax.Array


def _init_state(length: int, dtype=jnp.float32) -> SparsifierState:
    z = jnp.zeros((length,), dtype)
    return SparsifierState(eps=z, a_prev=z, s_prev=z, t=jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class SparsifierConfig:
    """Config shared by the registry; unused fields are ignored per-kind.

    kind       — "none" | "topk" | "regtopk" | "hard_threshold"
    sparsity   — S = k/J (paper's sparsification factor)
    mu         — RegTop-k innovation-CDF scale (paper's mu; mu->0 == Top-k)
    y          — prior exponent |a|^y (paper Remark 4; default 1.0)
    q_const    — the "very large constant Q" for unsent coordinates
    omega      — this worker's aggregation weight omega_n
    selector   — "exact" (lax.top_k) | "threshold" (bisection; ~k mask)
    threshold  — hard-threshold lambda (hard_threshold kind only)
    momentum   — DGC momentum-correction factor (dgc kind only)
    score_fn   — optional override of the scoring function (fused Pallas
                 kernel plugs in here; must match RegTopK._score).
    """

    kind: str = "regtopk"
    sparsity: float = 0.01
    mu: float = 1.0
    y: float = 1.0
    q_const: float = 1e9
    omega: float = 1.0
    selector: str = "exact"
    threshold: float = 1e-3
    momentum: float = 0.9
    score_fn: Optional[object] = None


class Sparsifier:
    """Base: error-accumulating sparsifier skeleton (Algorithm 1 shape)."""

    def __init__(self, cfg: SparsifierConfig):
        self.cfg = cfg

    # -- interface ---------------------------------------------------------
    def init(self, length: int, dtype=jnp.float32) -> SparsifierState:
        return _init_state(length, dtype)

    def step(
        self,
        state: SparsifierState,
        g_local: jax.Array,
        g_agg_prev: jax.Array,
        omega_prev: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array, SparsifierState]:
        """Returns (ghat_dense, mask, new_state). ``omega_prev`` is the
        previous round's per-coordinate sender mass under coordinate
        weighting (None == scalar worker weighting, bit-for-bit)."""
        raise NotImplementedError

    def step_dyn(
        self,
        state: SparsifierState,
        g_local: jax.Array,
        g_agg_prev: jax.Array,
        k: jax.Array,
        capacity: int,
        omega_prev: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array, SparsifierState]:
        """``step`` with a *traced* per-round k under a static ``capacity``
        (the adaptive controller's path — see
        ``selectors.exact_topk_mask_dynamic``). Only the magnitude-scored
        fixed-k kinds support it."""
        raise NotImplementedError(
            f"sparsifier kind {self.cfg.kind!r} does not support a "
            "dynamic per-round k (adaptive_k drives 'topk'/'regtopk')"
        )

    # -- runtime hooks (the only sanctioned slot rewrites outside step) ----
    def on_wire_residual(
        self, state: SparsifierState, delta: jax.Array
    ) -> SparsifierState:
        """A lossy codec put ``intended + delta`` on the wire: error
        feedback must cover the codec, so the residual folds into ``eps``.
        """
        return state._replace(eps=state.eps - delta)

    def on_dropped(
        self,
        old_state: SparsifierState,
        new_state: SparsifierState,
        ghat: jax.Array,
    ) -> SparsifierState:
        """State rewrite for a worker whose round-``t`` payload a partial
        schedule dropped. ``new_state`` is what ``step`` produced *before*
        any wire-residual fold (nothing traveled, so no codec loss), and
        ``ghat`` is the contribution that never arrived.

        Base semantics (topk / regtopk / hard_threshold): the whole
        accumulated gradient returns to error feedback
        (``eps = new.eps + ghat == a``) and the posterior statistics stay
        frozen at the last round the server actually saw this worker.
        """
        return SparsifierState(
            eps=new_state.eps + ghat,
            a_prev=old_state.a_prev,
            s_prev=old_state.s_prev,
            t=new_state.t,
        )

    # -- shared helpers ----------------------------------------------------
    def _k(self, length: int) -> int:
        return sel_lib.sparsity_to_k(length, self.cfg.sparsity)

    def _select(self, score: jax.Array) -> jax.Array:
        select = sel_lib.get_selector(self.cfg.selector)
        return select(score, self._k(score.shape[0]))

    def _select_dyn(
        self, score: jax.Array, k: jax.Array, capacity: int
    ) -> jax.Array:
        if self.cfg.selector != "exact":
            raise ValueError(
                "dynamic per-round k requires selector='exact' (the "
                "capacity-bounded lax.top_k path); got "
                f"{self.cfg.selector!r}"
            )
        return sel_lib.exact_topk_mask_dynamic(score, k, capacity)

    def _finish(
        self, state: SparsifierState, a: jax.Array, mask: jax.Array
    ) -> Tuple[jax.Array, jax.Array, SparsifierState]:
        ghat = mask * a
        new_state = SparsifierState(
            eps=a - ghat, a_prev=a, s_prev=mask, t=state.t + 1
        )
        return ghat, mask, new_state


class NoneSparsifier(Sparsifier):
    """Identity compressor — distributed SGD without sparsification."""

    def step(self, state, g_local, g_agg_prev, omega_prev=None):
        mask = jnp.ones_like(g_local)
        return g_local, mask, state._replace(t=state.t + 1)

    def on_dropped(self, old_state, new_state, ghat):
        # no error state: a dropped worker's gradient is simply lost
        # (that is the cost the participation benchmarks measure).
        return new_state


class TopK(Sparsifier):
    """Paper Algorithm 1: a = eps + g; mask = Top_k(|a|); eps' = a - mask*a."""

    def step(self, state, g_local, g_agg_prev, omega_prev=None):
        a = state.eps + g_local
        mask = self._select(jnp.abs(a))
        return self._finish(state, a, mask)

    def step_dyn(self, state, g_local, g_agg_prev, k, capacity,
                 omega_prev=None):
        a = state.eps + g_local
        mask = self._select_dyn(jnp.abs(a), k, capacity)
        return self._finish(state, a, mask)


class RegTopK(Sparsifier):
    """Paper Algorithm 2 (RegTop-k).

    Line 8:  Delta = s_prev * (g_agg_prev - omega * a_prev) / (omega * a)
                     + Q * (1 - s_prev)
    Line 9:  mask  = Top_k( a * tanh(|1 + Delta| / mu) )  — magnitude select,
             generalized with the Remark-4 prior exponent ``y``:
             score = |a|^y * tanh(|1 + Delta| / mu).
    Round 0 applies plain Top-k (no posterior information yet).
    """

    def _score(
        self,
        state: SparsifierState,
        a: jax.Array,
        g_prev: jax.Array,
        omega_prev: Optional[jax.Array] = None,
    ) -> jax.Array:
        cfg = self.cfg
        if cfg.score_fn is not None:
            if omega_prev is not None:
                raise ValueError(
                    "the fused score kernel bakes a scalar omega; "
                    "coordinate weighting (omega_prev) requires the "
                    "reference score path (fastpath off)"
                )
            return cfg.score_fn(a, state.a_prev, state.s_prev, g_prev, cfg)
        omega = cfg.omega
        if omega_prev is not None:
            # coordinate weighting: the server divided coordinate j by the
            # sender mass den[j], so this worker's weight there was
            # omega / den[j]. Where den == 0 nobody sent j — s_prev == 0
            # there too, so the guard value never reaches the score.
            omega = cfg.omega / jnp.where(omega_prev > 0, omega_prev, 1.0)
        denom = omega * a
        safe = jnp.where(denom == 0, 1.0, denom)
        delta_sent = (g_prev - omega * state.a_prev) / safe
        delta = jnp.where(state.s_prev > 0, delta_sent, cfg.q_const)
        reg = jnp.tanh(jnp.abs(1.0 + delta) / cfg.mu)
        mag = jnp.abs(a)
        if cfg.y != 1.0:
            mag = mag**cfg.y
        return mag * reg

    def step(self, state, g_local, g_agg_prev, omega_prev=None):
        a = state.eps + g_local
        score = jnp.where(
            state.t == 0,
            jnp.abs(a),
            self._score(state, a, g_agg_prev, omega_prev),
        )
        mask = self._select(score)
        return self._finish(state, a, mask)

    def step_dyn(self, state, g_local, g_agg_prev, k, capacity,
                 omega_prev=None):
        a = state.eps + g_local
        score = jnp.where(
            state.t == 0,
            jnp.abs(a),
            self._score(state, a, g_agg_prev, omega_prev),
        )
        mask = self._select_dyn(score, k, capacity)
        return self._finish(state, a, mask)

    def on_wire_residual(self, state, delta):
        # the posterior must condition on what the server actually
        # decoded: shift a_prev to the transmitted values at the sent
        # coordinates (mirrors compact_finalize_sent in the distributed
        # runtime) on top of the base error-feedback fold.
        return state._replace(
            eps=state.eps - delta, a_prev=state.a_prev + delta
        )


class HardThreshold(Sparsifier):
    """Sahu et al. [27]: fixed threshold lambda on the accumulated gradient.

    Variable cardinality → dense-aggregation simulation only (a fixed-k
    payload variant is available through ``selectors.mask_to_payload``).
    """

    def step(self, state, g_local, g_agg_prev, omega_prev=None):
        a = state.eps + g_local
        mask = (jnp.abs(a) >= self.cfg.threshold).astype(a.dtype)
        return self._finish(state, a, mask)


class CoordTopK(Sparsifier):
    """Beyond-paper: *common-information coordinated* Top-k (ours).

    The paper's analysis (Sec. B.3 + our Sec. 5 diagnosis in EXPERIMENTS.md)
    shows RegTop-k's gains come from *implicit mask coordination*: when all
    workers select the same coordinates, the destructive components of
    heterogeneous local gradients cancel exactly and the error release is a
    sum of past *true* aggregates. We make that explicit: the mask is a
    deterministic function of information every worker shares — the
    broadcast aggregated gradient ``g^{t-1}`` and the (therefore common)
    previous masks — so coordination is guaranteed, not emergent.

    score[j] = staleness[j] + |g_prev[j]| / max|g_prev|

    Staleness (rounds since last selected, >= 1 for unselected) dominates →
    round-robin coverage of every coordinate; the normalized aggregate
    magnitude (< 1) breaks ties by global importance — the paper's
    "statistical global Top-k" realized with exact worker agreement.
    Converges at *every* sparsity in distributed linear regression where
    Top-k plateaus (see EXPERIMENTS.md §Claims).
    """

    def step(self, state, g_local, g_agg_prev, omega_prev=None):
        a = state.eps + g_local
        # a_prev slot stores the (common) staleness counter
        stale = state.a_prev
        gmag = jnp.abs(g_agg_prev)
        gn = gmag / jnp.maximum(jnp.max(gmag), 1e-30)
        mask = self._select(stale + gn)
        ghat = mask * a
        new_state = SparsifierState(
            eps=a - ghat,
            a_prev=jnp.where(mask > 0, 0.0, stale + 1.0),
            s_prev=mask,
            t=state.t + 1,
        )
        return ghat, mask, new_state

    def on_dropped(self, old_state, new_state, ghat):
        # the staleness counter is *common information*: every worker
        # derives the identical mask from the broadcast aggregate, so a
        # dropped worker's counter must advance in lockstep (freezing it —
        # the pre-hook simulator behavior — desynchronizes the fleet's
        # round-robin coverage). Only the undelivered mass returns to eps.
        return new_state._replace(eps=new_state.eps + ghat)


class DGC(Sparsifier):
    """Deep Gradient Compression (Lin et al., ICLR'18 [26]) — Top-k with
    *momentum correction* and momentum-factor masking. Included as the
    strongest classical baseline the paper cites.

    u = m·u + g;  v = v_residual + u;  mask = Top_k(|v|)
    send mask·v;  v_residual = v − mask·v;  u = (1 − mask)·u

    The momentum factor ``m`` comes from ``SparsifierConfig.momentum``.
    """

    def step(self, state, g_local, g_agg_prev, omega_prev=None):
        u = self.cfg.momentum * state.a_prev + g_local  # a_prev slot holds u
        v = state.eps + u
        mask = self._select(jnp.abs(v))
        ghat = mask * v
        new_state = SparsifierState(
            eps=v - ghat,
            a_prev=(1.0 - mask) * u,
            s_prev=mask,
            t=state.t + 1,
        )
        return ghat, mask, new_state

    def on_dropped(self, old_state, new_state, ghat):
        # restore the undelivered mass: eps = (v - ghat) + ghat = v. The
        # a_prev slot holds the masked velocity (1 - mask)·u — exactly the
        # unsent-coordinate recursion DGC already runs, so keeping it is
        # the minimal perturbation: at would-have-sent coordinates the
        # velocity resets (their mass now lives in eps), everywhere else
        # the momentum correction proceeds as if the drop never happened.
        # Freezing a_prev at the *old* u instead (the pre-hook simulator
        # behavior) double-counts: the momentum folded into v would be
        # re-applied through m·u next round.
        return new_state._replace(eps=new_state.eps + ghat)


KINDS = {
    "none": NoneSparsifier,
    "topk": TopK,
    "regtopk": RegTopK,
    "hard_threshold": HardThreshold,
    "coordtopk": CoordTopK,
    "dgc": DGC,
}


def make_sparsifier(cfg: SparsifierConfig) -> Sparsifier:
    try:
        cls = KINDS[cfg.kind]
    except KeyError:
        raise ValueError(
            f"unknown sparsifier kind {cfg.kind!r}; available: {sorted(KINDS)}"
        ) from None
    return cls(cfg)
