"""Single-process N-worker distributed-SGD simulator.

Runs the paper's setting (Sec. 2) exactly: N workers compute local
gradients, sparsify with a shared algorithm but *independent per-worker
state*, the server aggregates with weights omega_n and broadcasts both the
model update and the aggregated gradient (which RegTop-k consumes next
round as ``g_agg_prev``).

Workers are a leading array axis (vmap) → the same code jit-compiles and,
in the distributed runtime, shards that axis over the ("pod","data") mesh
axes. The paper-repro benchmarks (linear regression, toy logistic) and the
property tests drive this simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregate
from repro.core.sparsify import (
    Sparsifier,
    SparsifierConfig,
    SparsifierState,
    make_sparsifier,
)


class SimState(NamedTuple):
    theta: jax.Array  # [J]  global model
    worker_states: SparsifierState  # leaves with leading [N]
    g_agg_prev: jax.Array  # [J]  last broadcast aggregated gradient
    step: jax.Array  # scalar int32


@dataclasses.dataclass
class DistributedSim:
    """grad_fn(theta, worker_index) -> local gradient [J]."""

    grad_fn: Callable[[jax.Array, jax.Array], jax.Array]
    n_workers: int
    length: int
    sparsifier_cfg: SparsifierConfig
    learning_rate: float = 1e-2
    aggregation: str = "dense_allreduce"

    def __post_init__(self):
        # uniform server weights omega_n = 1/N (paper's arithmetic mean);
        # keep the sparsifier's omega consistent with the aggregation.
        cfg = dataclasses.replace(self.sparsifier_cfg, omega=1.0 / self.n_workers)
        self.sparsifier: Sparsifier = make_sparsifier(cfg)
        self.weights = jnp.full((self.n_workers,), 1.0 / self.n_workers)

    def init(self, theta0: jax.Array) -> SimState:
        single = self.sparsifier.init(self.length, dtype=theta0.dtype)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_workers,) + x.shape), single
        )
        return SimState(
            theta=theta0,
            worker_states=stacked,
            g_agg_prev=jnp.zeros((self.length,), theta0.dtype),
            step=jnp.zeros((), jnp.int32),
        )

    def step_fn(self, state: SimState) -> Tuple[SimState, jax.Array]:
        """One synchronous round; returns (new_state, aggregated_gradient)."""
        widx = jnp.arange(self.n_workers)
        grads = jax.vmap(self.grad_fn, in_axes=(None, 0))(state.theta, widx)

        ghat, mask, new_ws = jax.vmap(
            self.sparsifier.step, in_axes=(0, 0, None)
        )(state.worker_states, grads, state.g_agg_prev)

        if self.aggregation == "dense_allreduce":
            g_agg = aggregate.dense_mean(ghat, self.weights)
        elif self.aggregation == "sparse_allgather":
            from repro.core import selectors as sel_lib

            k = sel_lib.sparsity_to_k(self.length, self.sparsifier.cfg.sparsity)
            vals, idx = jax.vmap(
                lambda m, a: sel_lib.mask_to_payload(m, a, k)
            )(mask, ghat)
            g_agg = aggregate.scatter_add_payloads(
                vals, idx, self.weights, self.length
            )
        else:
            raise ValueError(f"unknown aggregation {self.aggregation!r}")

        theta = state.theta - self.learning_rate * g_agg
        new_state = SimState(
            theta=theta,
            worker_states=new_ws,
            g_agg_prev=g_agg,
            step=state.step + 1,
        )
        return new_state, g_agg

    def run(
        self,
        theta0: jax.Array,
        n_steps: int,
        trace_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
    ):
        """jit-scanned rollout; returns (final_state, trace [n_steps, ...])."""
        step = self.step_fn

        def body(state, _):
            new_state, _g = step(state)
            out = trace_fn(new_state.theta) if trace_fn else new_state.theta
            return new_state, out

        init = self.init(theta0)
        return jax.jit(
            lambda s: jax.lax.scan(body, s, None, length=n_steps)
        )(init)
