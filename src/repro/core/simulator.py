"""Single-process N-worker distributed-SGD simulator.

Runs the paper's setting (Sec. 2) exactly: N workers compute local
gradients, sparsify with a shared algorithm but *independent per-worker
state*, the server aggregates with weights omega_n and broadcasts both the
model update and the aggregated gradient (which RegTop-k consumes next
round as ``g_agg_prev``).

Workers are a leading array axis (vmap) → the same code jit-compiles and,
in the distributed runtime, shards that axis over the ("pod","data") mesh
axes. The paper-repro benchmarks (linear regression, toy logistic) and the
property tests drive this simulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import comm
from repro.core import aggregate, selectors as sel_lib
from repro.core.sparsify import (
    Sparsifier,
    SparsifierConfig,
    SparsifierState,
    make_sparsifier,
)


class SimState(NamedTuple):
    theta: jax.Array  # [J]  global model
    worker_states: SparsifierState  # leaves with leading [N]
    g_agg_prev: jax.Array  # [J]  last broadcast aggregated gradient
    step: jax.Array  # scalar int32
    # per-worker undelivered-payload state (bounded-staleness schedules
    # only; None otherwise): the server-side buffer of weighted, discounted
    # contributions produced by stragglers and not yet broadcast, plus the
    # rounds-to-delivery countdown (0 = empty slot).
    pending: Optional[jax.Array] = None  # [N, J]
    pending_age: Optional[jax.Array] = None  # [N] int32
    # adaptive-k controller state (comm.ControllerState; None when the
    # controller is disabled — the static-k path is then bit-for-bit
    # unchanged, exactly like the pending fields above).
    ctrl: Optional[comm.ControllerState] = None
    # per-coordinate sender mass den[j] the server divided by last round
    # (weighting="coordinate" only; None == scalar worker weighting). This
    # is the coordinate-wise omega RegTop-k's posterior conditions on.
    w_agg_prev: Optional[jax.Array] = None  # [J]


@dataclasses.dataclass
class DistributedSim:
    """grad_fn(theta, worker_index) -> local gradient [J].

    ``dp_shape`` factors the ``n_workers`` ring into a notional multi-axis
    dp mesh (outermost first, product must equal ``n_workers``) for cost
    modeling and "auto" planning — the simulated *numerics* are grouping-
    independent (every collective reference form sums over all workers),
    but a ``link_topo`` with a slow outer axis then prices (and can plan)
    ``hierarchical`` exactly like the distributed runtime would.
    """

    grad_fn: Callable[[jax.Array, jax.Array], jax.Array]
    n_workers: int
    length: int
    sparsifier_cfg: SparsifierConfig
    learning_rate: float = 1e-2
    aggregation: str = "dense_allreduce"  # legacy alias for ``collective``
    codec: str = "coo_fp32"  # repro.comm wire codec, or "auto"
    collective: Optional[str] = None  # repro.comm strategy, "auto", or None
    link_model: Optional[comm.AlphaBeta] = None  # drives "auto" planning
    link_topo: Optional[comm.LinkTopo] = None  # per-axis; wins over scalar
    dp_shape: Optional[Tuple[int, ...]] = None  # notional dp mesh factoring
    # partial-participation / staleness round schedule; None == full. A
    # full schedule is bit-for-bit identical to the no-participation path
    # (the participation logic is skipped entirely at trace time).
    participation: Optional[comm.Participation] = None
    # fused Pallas fastpath ("off" | "on" | "auto"): the simulator's
    # dense-state, vmapped step fuses the *scoring* stage only (the
    # regtopk score kernel via SparsifierConfig.score_fn — 4 reads +
    # 1 write instead of ~9 streams); the full select→encode fusion needs
    # the compact state layout and lives in the shard_map runtime
    # (DistConfig.fastpath). "auto" resolves to "off" off-TPU.
    fastpath: str = "off"
    # error-budget-driven per-round k (comm.AdaptiveKController); None is
    # the historical static-k path, bit-for-bit. When set, selection runs
    # at the static capacity k_max with the controller's k as a dynamic
    # operand (no retrace), and each round folds the measured
    # ||eps|| / ||g_agg|| ratio back into the controller state.
    adaptive_k: Optional[comm.AdaptiveKController] = None
    # aggregation weighting axis ("worker" | "coordinate", see
    # repro.comm.collectives): "coordinate" renormalizes each coordinate
    # by the mass of the workers that actually sent it and threads that
    # mass back into RegTop-k's posterior; "worker" is the historical
    # per-worker Eq. (8) reduction, bit-for-bit.
    weighting: str = "worker"
    # bucketed overlap spec ("off" | "buckets:B", see repro.comm.overlap).
    # The sim aggregates one flat vector — a single leaf — so any B clamps
    # to one bucket and the numerics are untouched by construction; what
    # the spec buys here is pricing: round_timeline() reports the same
    # BucketPlan/Timeline pair the distributed runtime would predict, so
    # overlap sweeps can be costed without an 8-device mesh.
    overlap: str = "off"

    def __post_init__(self):
        # parse (and thereby validate) the overlap spec up front — a bad
        # spec fails at construction, not at the first round_timeline().
        self._overlap_cfg = comm.parse_overlap(self.overlap)
        if self.fastpath not in comm.FASTPATH_MODES:
            raise ValueError(
                f"unknown fastpath {self.fastpath!r}; "
                f"available: {comm.FASTPATH_MODES}"
            )
        if self.participation is not None:
            self.participation.validate(self.n_workers)
        comm.check_weighting(self.weighting)
        if self.weighting == "coordinate":
            if self.sparsifier_cfg.kind == "none":
                raise ValueError(
                    "weighting='coordinate' needs sparse payloads; "
                    "kind='none' sends every coordinate, so the sender "
                    "mass is uniformly 1 and coordinate weighting "
                    "degenerates to the worker reduction — use "
                    "weighting='worker'"
                )
            if (
                self.participation is not None
                and self.participation.delays_payloads
            ):
                raise ValueError(
                    "weighting='coordinate' does not compose with the "
                    "'stale' schedule: late payloads are folded into the "
                    "broadcast after the per-coordinate renormalization, "
                    "so the sender mass the server divided by would not "
                    "cover them"
                )
            if self.fastpath == "on":
                raise ValueError(
                    "the fused score kernel bakes a scalar omega; "
                    "weighting='coordinate' requires fastpath='off' "
                    "(or 'auto', which declines the fusion)"
                )
        # adaptive-k: resolve the static [k_min, k_max] bounds once (k_max
        # is the payload capacity the traced step allocates).
        self._k_bounds: Optional[Tuple[int, int]] = None
        if self.adaptive_k is not None:
            if self.sparsifier_cfg.kind not in ("topk", "regtopk"):
                raise ValueError(
                    "adaptive_k drives magnitude-scored fixed-k kinds "
                    "('topk'/'regtopk'); got "
                    f"{self.sparsifier_cfg.kind!r}"
                )
            if self.sparsifier_cfg.selector != "exact":
                raise ValueError(
                    "adaptive_k requires selector='exact' (the capacity-"
                    "bounded lax.top_k path); got "
                    f"{self.sparsifier_cfg.selector!r}"
                )
            self._k_bounds = self.adaptive_k.bounds(self.length)
        # uniform server weights omega_n = 1/N (paper's arithmetic mean);
        # keep the sparsifier's omega consistent with the aggregation. A
        # partial schedule aggregates with the schedule's effective weight
        # (Participation.effective_omega): the renormalized 1/|P_t| for
        # dropping schedules (exact for fixed-size, expected for
        # bernoulli), 1/S for client sampling, and for 'stale' the
        # unconditional on-time + discounted-late mass — stale payloads
        # *do* arrive, so the old 1/(on-time) value was wrong whenever
        # discount > 0.
        omega = (
            1.0 / self.n_workers
            if not self._participation_active
            else self.participation.effective_omega(self.n_workers)
        )
        cfg = dataclasses.replace(self.sparsifier_cfg, omega=omega)
        if (
            cfg.kind == "regtopk"
            and cfg.score_fn is None
            # the fused kernel scores with a *scalar* omega — coordinate
            # weighting needs the omega_prev-aware reference score path.
            and self.weighting == "worker"
            and (
                self.fastpath == "on"
                or (
                    self.fastpath == "auto"
                    and comm.fastpath.backend_supports()
                )
            )
        ):
            cfg = dataclasses.replace(
                cfg, score_fn=comm.fastpath.make_score_fn()
            )
        self.sparsifier: Sparsifier = make_sparsifier(cfg)
        self.weights = jnp.full((self.n_workers,), 1.0 / self.n_workers)
        dp = tuple(int(s) for s in self.dp_shape) if self.dp_shape else (
            self.n_workers,
        )
        if math.prod(dp) != self.n_workers:
            raise ValueError(
                f"dp_shape {dp} does not factor n_workers={self.n_workers}"
            )
        self._dp_sizes = dp
        if self.codec == "auto" or self.resolved_collective == "auto":
            # single-leaf mirror of distributed.build_plan's auto planning
            from repro.comm import autotune

            codecs = None if self.codec == "auto" else [self.codec]
            if cfg.kind in ("none", "hard_threshold"):
                # no fixed-k payload exists: a *free* collective axis can
                # only resolve to the dense wire. An explicitly requested
                # payload collective is left alone so the hard_threshold
                # guard below raises instead of silently overriding it.
                colls = (
                    ["dense_allreduce"]
                    if self.resolved_collective == "auto"
                    else [self.resolved_collective]
                )
            else:
                colls = (
                    None if self.resolved_collective == "auto"
                    else [self.resolved_collective]
                )
            d = autotune.choose_leaf(
                self.length,
                # adaptive runs price the wire at capacity (k_max) — the
                # payload shape the round actually ships.
                (
                    self._k_bounds[1]
                    if self._k_bounds is not None
                    else sel_lib.sparsity_to_k(self.length, cfg.sparsity)
                ),
                self._dp_sizes,
                self.resolved_link_model,
                codecs=codecs,
                collectives=colls,
                allow_lossy=self.codec != "auto",
                participants=self._participants,
            )
            if self.codec == "auto":
                self.codec = d.codec
            self.collective, self.aggregation = d.collective, d.collective
        coll = self.resolved_collective
        self._codec = comm.get_codec(self.codec)
        self._strategy = comm.get_collective(coll)
        if coll != "dense_allreduce" and cfg.kind == "hard_threshold":
            raise ValueError(
                "hard_threshold produces a variable-cardinality mask; the "
                f"fixed-k payload collective {coll!r} would silently drop "
                "coordinates beyond k. Use aggregation/collective="
                "'dense_allreduce' for hard_threshold (or a fixed-k "
                "sparsifier for payload collectives)."
            )

    @property
    def resolved_collective(self) -> str:
        return self.collective or self.aggregation

    @property
    def _participation_active(self) -> bool:
        return self.participation is not None and not self.participation.is_full

    @property
    def _participants(self) -> Optional[float]:
        """Expected on-time workers per round for cost/planning (None when
        every round is full)."""
        if not self._participation_active:
            return None
        return self.participation.expected_participants(self.n_workers)

    @property
    def resolved_link_model(self) -> comm.LinkModel:
        """Per-axis topology when given, else scalar model, else defaults."""
        if self.link_topo is not None:
            return self.link_topo
        return self.link_model or comm.AlphaBeta()

    def init(self, theta0: jax.Array) -> SimState:
        single = self.sparsifier.init(self.length, dtype=theta0.dtype)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_workers,) + x.shape), single
        )
        stale = (
            self._participation_active and self.participation.delays_payloads
        )
        return SimState(
            theta=theta0,
            worker_states=stacked,
            g_agg_prev=jnp.zeros((self.length,), theta0.dtype),
            step=jnp.zeros((), jnp.int32),
            pending=(
                jnp.zeros((self.n_workers, self.length), theta0.dtype)
                if stale
                else None
            ),
            pending_age=(
                jnp.zeros((self.n_workers,), jnp.int32) if stale else None
            ),
            ctrl=(
                self.adaptive_k.init(
                    sel_lib.sparsity_to_k(
                        self.length, self.sparsifier.cfg.sparsity
                    ),
                    *self._k_bounds,
                )
                if self.adaptive_k is not None
                else None
            ),
            # neutral mass: round 0 scores plain Top-k (t == 0), and a
            # den of 1 makes the where-evaluated posterior branch finite.
            w_agg_prev=(
                jnp.ones((self.length,), theta0.dtype)
                if self.weighting == "coordinate"
                else None
            ),
        )

    def step_fn(self, state: SimState) -> Tuple[SimState, jax.Array]:
        """One synchronous round; returns (new_state, aggregated_gradient).

        Under a partial-participation schedule, a round aggregates only
        the participating workers with renormalized weights; dropped
        workers are rewritten by their kind's ``Sparsifier.on_dropped``
        (error feedback keeps the undelivered mass; posterior/momentum/
        staleness slot semantics are kind-specific), while ``stale``
        schedules instead park the straggler's weighted, discounted
        contribution in the per-worker ``pending`` buffer and fold it into
        the broadcast exactly once, ``staleness`` rounds late. ``sampled``
        schedules gather the S drawn clients, run the round over S, and
        scatter the updated states back — idle clients never compute.
        ``g_agg_prev`` is always exactly what the server broadcast — late
        deliveries included — which is what RegTop-k's posterior conditions
        on next round; under ``weighting="coordinate"`` the broadcast also
        carries the per-coordinate sender mass (``SimState.w_agg_prev``)
        the server divided by, which next round's posterior conditions on.
        """
        part = self.participation
        partial = self._participation_active
        stale = partial and part.delays_payloads
        sampled = partial and part.kind == "sampled"

        if sampled:
            # fleet-scale client sampling: gather the S sampled workers'
            # states, run the round over S only (grads, sparsify, aggregate
            # at weight 1/S), and scatter the S updated states back at the
            # end. Unsampled clients are idle — their state is untouched
            # and nothing O(N·J) is materialized per round.
            widx = part.round_participants(state.step, self.n_workers)
            round_ws = jax.tree.map(lambda x: x[widx], state.worker_states)
            weights = jnp.full(
                (widx.shape[0],), 1.0 / widx.shape[0], jnp.float32
            )
            pmask = None  # the aggregation sees only the S senders
        else:
            widx = jnp.arange(self.n_workers)
            round_ws = state.worker_states
            weights = self.weights
            pmask = (
                part.round_mask(state.step, self.n_workers)
                if partial
                else None
            )
        grads = jax.vmap(self.grad_fn, in_axes=(None, 0))(state.theta, widx)

        if self.adaptive_k is None:
            ghat, mask, new_ws = jax.vmap(
                lambda s, g: self.sparsifier.step(
                    s, g, state.g_agg_prev, omega_prev=state.w_agg_prev
                )
            )(round_ws, grads)
        else:
            # the round sends the k the controller planned *last* round —
            # a dynamic operand of the compiled step (capacity is static).
            k_dyn, cap = state.ctrl.k, self._k_bounds[1]
            ghat, mask, new_ws = jax.vmap(
                lambda s, g: self.sparsifier.step_dyn(
                    s,
                    g,
                    state.g_agg_prev,
                    k_dyn,
                    cap,
                    omega_prev=state.w_agg_prev,
                )
            )(round_ws, grads)
        # snapshot before any wire-residual fold: a dropped worker's
        # payload never traveled, so no codec loss applies to it (the
        # sparsifier invariant eps' + ghat == accumulated a still holds
        # here, which is what Sparsifier.on_dropped relies on).
        pre_ws = new_ws

        # kind="none" has no fixed-k payload (the mask is all-ones): always
        # aggregate dense, exactly like the distributed runtime's _spa_leaf.
        dense_path = (
            self.resolved_collective == "dense_allreduce"
            or self.sparsifier_cfg.kind == "none"
        )
        sent_stack = None  # per-worker dense contribution (stale delivery)
        den = None  # coordinate weighting: per-coordinate sender mass [J]
        if dense_path:
            w = (
                part.participating_weights(weights, state.step)
                if partial and not sampled
                else weights
            )
            if self.weighting == "coordinate":
                # dense wire, but the sparsified gradient is zero off the
                # selected coordinates — presence still identifies the
                # sender set (mirrors DenseAllreduce.reference_coord).
                presence = (ghat != 0).astype(ghat.dtype)
                num = aggregate.dense_mean(ghat, w)
                den = aggregate.dense_mean(presence, w)
                g_agg = num / jnp.maximum(den, jnp.finfo(den.dtype).tiny)
            else:
                g_agg = aggregate.dense_mean(ghat, w)
            sent_stack = ghat
        else:
            codec, L = self._codec, self.length
            k = (
                self._k_bounds[1]
                if self._k_bounds is not None
                else sel_lib.sparsity_to_k(L, self.sparsifier.cfg.sparsity)
            )
            vals, idx = jax.vmap(
                lambda m, a: sel_lib.mask_to_payload(m, a, k)
            )(mask, ghat)
            payloads = jax.vmap(lambda v, i: codec.encode(v, i, L))(vals, idx)
            if not codec.lossless:
                # error feedback covers the codec: fold the decode residual
                # (actually-transmitted minus intended) back into the state
                # via the kind's own hook (RegTop-k also shifts a_prev so
                # its posterior conditions on what the server decoded).
                scatter = lambda v, i: jnp.zeros((L,), v.dtype).at[i].add(v)
                intended = jax.vmap(scatter)(vals, idx)
                sent = jax.vmap(
                    lambda p: codec.decoded_dense(p, L)
                )(payloads)
                delta = (sent - intended).astype(new_ws.eps.dtype)
                new_ws = self.sparsifier.on_wire_residual(new_ws, delta)
            if self.weighting == "coordinate":
                g_agg, den = self._strategy.reference_coord(
                    codec, payloads, weights, L, participation=pmask
                )
                g_agg = g_agg.astype(ghat.dtype)
            else:
                g_agg = self._strategy.reference(
                    codec, payloads, weights, L, participation=pmask
                ).astype(ghat.dtype)
            if stale:
                sent_stack = jax.vmap(
                    lambda p: codec.decoded_dense(p, L)
                )(payloads).astype(ghat.dtype)

        pending, pending_age = state.pending, state.pending_age
        if partial and not stale and not sampled:
            # dropped workers sent nothing — the rewrite is kind-specific
            # (DGC keeps momentum where RegTop-k keeps a_prev; CoordTopK's
            # common staleness counter must keep advancing), so the slot
            # semantics are owned by Sparsifier.on_dropped, not spelled
            # out here. Sampled schedules never reach this: unsampled
            # clients are idle and their state was never stepped.
            dropped_ws = self.sparsifier.on_dropped(
                state.worker_states, pre_ws, ghat
            )
            new_ws = jax.tree.map(
                lambda live, gone: jnp.where(
                    pmask.reshape((-1,) + (1,) * (live.ndim - 1)) > 0,
                    live,
                    gone,
                ),
                new_ws,
                dropped_ws,
            )
        elif stale:
            # bounded-staleness delivery: this round's stragglers park
            # omega_n * discount * (their decoded contribution); buffered
            # payloads land exactly once — when their countdown hits one,
            # or early if their worker straggles again first.
            dropped = 1.0 - pmask
            deliver = (pending_age > 0) & (
                (pending_age == 1) | (dropped > 0)
            )
            delivered = (
                deliver.astype(g_agg.dtype)[:, None] * pending
            ).sum(axis=0)
            g_agg = g_agg + delivered.astype(g_agg.dtype)
            new_contrib = (
                (dropped * self.weights * part.discount)[:, None]
                * sent_stack
            )
            pending = jnp.where(
                dropped[:, None] > 0,
                new_contrib,
                jnp.where(deliver[:, None], 0.0, pending),
            )
            pending_age = jnp.where(
                dropped > 0,
                part.staleness,
                jnp.where(deliver, 0, jnp.maximum(pending_age - 1, 0)),
            ).astype(jnp.int32)

        ctrl = state.ctrl
        if self.adaptive_k is not None:
            # posterior error statistics of the finished round: mean
            # per-worker ||eps|| (codec residual included) against the
            # broadcast ||g_agg|| (late deliveries included).
            eps_norm = jnp.linalg.norm(
                new_ws.eps.astype(jnp.float32), axis=-1
            ).mean()
            g_norm = jnp.linalg.norm(g_agg.astype(jnp.float32))
            lo, hi = self._k_bounds
            ctrl = self.adaptive_k.observe(
                ctrl, eps_norm, g_norm, k_min=lo, k_max=hi
            )

        if sampled:
            # scatter the S updated states back into the N-worker fleet
            # (the controller above observed the active S only — idle
            # clients carry no fresh round statistics).
            new_ws = jax.tree.map(
                lambda full, sub: full.at[widx].set(sub),
                state.worker_states,
                new_ws,
            )

        theta = state.theta - self.learning_rate * g_agg
        new_state = SimState(
            theta=theta,
            worker_states=new_ws,
            g_agg_prev=g_agg,
            step=state.step + 1,
            pending=pending,
            pending_age=pending_age,
            ctrl=ctrl,
            w_agg_prev=(
                den.astype(state.w_agg_prev.dtype)
                if self.weighting == "coordinate"
                else None
            ),
        )
        return new_state, g_agg

    def wire_bytes_per_round(
        self, model: Optional[comm.LinkModel] = None
    ) -> comm.CostEstimate:
        """Per-worker alpha–beta cost of one round at this sim's settings,
        over the sim's (possibly multi-axis) notional dp mesh. ``model``
        defaults to the sim's own resolved link model/topology. Adaptive
        runs price the static payload capacity (k_max) — the fixed-shape
        buffer the round ships; per-round *effective* bits at the
        controller's k are ``comm.round_wire_bits(codec, L, k)``."""
        k = (
            self._k_bounds[1]
            if self._k_bounds is not None
            else sel_lib.sparsity_to_k(
                self.length, self.sparsifier.cfg.sparsity
            )
        )
        return comm.predict(
            self._codec,
            self.resolved_collective,
            self.length,
            k,
            self._dp_sizes,
            self.resolved_link_model if model is None else model,
            participants=self._participants,
        )

    def round_timeline(
        self, compute_seconds=None
    ) -> Tuple[comm.BucketPlan, comm.Timeline]:
        """The bucket schedule and predicted overlapped timeline of one
        round under ``overlap`` (raises when "off"), mirroring
        ``distributed.comm_round_timeline`` for the sim's single leaf:
        ``timeline.sync_seconds`` equals ``wire_bytes_per_round().seconds``
        up to fp summation order, and with one leaf the schedule clamps to
        one bucket, so ``timeline.seconds`` matches it too."""
        if self._overlap_cfg is None:
            raise ValueError(
                "round_timeline needs overlap != 'off' "
                "(e.g. overlap='buckets:4')"
            )
        k = (
            self._k_bounds[1]
            if self._k_bounds is not None
            else sel_lib.sparsity_to_k(
                self.length, self.sparsifier.cfg.sparsity
            )
        )
        lc = comm.leaf_cost(
            self._codec,
            self.resolved_collective,
            self.length,
            k,
            self._dp_sizes,
            self.resolved_link_model,
            participants=self._participants,
        )
        bplan = comm.bucketize([lc], self._overlap_cfg)
        return bplan, comm.overlap_timeline(bplan, compute_seconds)

    def run(
        self,
        theta0: jax.Array,
        n_steps: int,
        trace_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
        trace_state_fn: Optional[Callable[[SimState], object]] = None,
    ):
        """jit-scanned rollout; returns (final_state, trace [n_steps, ...]).

        ``trace_fn`` maps each round's theta to a trace row (default: theta
        itself). ``trace_state_fn`` instead receives the whole new
        :class:`SimState` — the adaptive benchmarks use it to trace the
        per-round k (``state.ctrl.k``) alongside convergence; it wins when
        both are given."""
        step = self.step_fn

        def body(state, _):
            new_state, _g = step(state)
            if trace_state_fn is not None:
                out = trace_state_fn(new_state)
            else:
                out = (
                    trace_fn(new_state.theta) if trace_fn
                    else new_state.theta
                )
            return new_state, out

        init = self.init(theta0)
        return jax.jit(
            lambda s: jax.lax.scan(body, s, None, length=n_steps)
        )(init)
