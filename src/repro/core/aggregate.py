"""Aggregation of sparsified gradients.

Two communication patterns (paper Sec. 2.2, Eq. (8): weighted average of the
sparsified local gradients):

* ``dense_allreduce``   — every worker contributes its sparse-but-dense
  vector to a mean-allreduce. Numerically exact, used for simulation,
  tests and the paper-repro benchmarks. Inside ``shard_map`` this is
  ``lax.pmean`` over the data-parallel axes (J words on the wire —
  the *uncompressed* baseline the paper compares against).

* ``sparse_allgather``  — the compressed collective: each worker sends its
  fixed-k payload ``(vals, idx)``; an ``all_gather`` over the dp axes moves
  ``2·N·k`` words instead of ``N·J``; every rank then scatter-adds the
  N payloads locally (server replicated at every rank, the TPU-native
  analogue of the paper's parameter server). Identical numerics to
  dense_allreduce when the selector is exact.

Both are exposed (a) as in-``shard_map`` collectives and (b) as
single-process N-worker reference reductions used by the simulator.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# single-process reference reductions (worker axis is a real array axis)
# ---------------------------------------------------------------------------
def dense_mean(ghat_stack: jax.Array, weights: jax.Array) -> jax.Array:
    """``ghat_stack``: [N, L]; ``weights``: [N] (omega_n, sum to 1)."""
    return jnp.einsum("n,nl->l", weights, ghat_stack)


def scatter_add_payloads(
    vals: jax.Array, idx: jax.Array, weights: jax.Array, length: int
) -> jax.Array:
    """``vals``/``idx``: [N, k]; returns the weighted dense sum, [L]."""
    flat_vals = (weights[:, None] * vals).reshape(-1)
    flat_idx = idx.reshape(-1)
    return jnp.zeros((length,), vals.dtype).at[flat_idx].add(flat_vals)


# ---------------------------------------------------------------------------
# in-shard_map collectives (manual axes)
# ---------------------------------------------------------------------------
def allreduce_dense(
    ghat: jax.Array, axis_names: Sequence[str], weight: jax.Array | float
) -> jax.Array:
    """Weighted allreduce of the sparse-dense vector over the dp axes.

    ``weight`` is this worker's omega_n; with uniform omega = 1/N this is
    ``lax.pmean``. J words/worker on the wire (uncompressed pattern).
    """
    return jax.lax.psum(ghat * weight, tuple(axis_names))


def allgather_scatter(
    vals: jax.Array,
    idx: jax.Array,
    length: int,
    axis_names: Sequence[str],
    weight: jax.Array | float,
) -> jax.Array:
    """Compressed aggregation: all_gather fixed-k payloads + local scatter.

    Wire cost per worker: 2·k words gathered from each of N workers
    (value f32 + index i32) — the paper's S = k/J compression, realized
    with static shapes as TPU/XLA requires.
    """
    wvals = vals * weight
    g_vals, g_idx = wvals, idx
    for ax in axis_names:
        g_vals = jax.lax.all_gather(g_vals, ax)
        g_idx = jax.lax.all_gather(g_idx, ax)
    g_vals = g_vals.reshape(-1)
    g_idx = g_idx.reshape(-1)
    return jnp.zeros((length,), vals.dtype).at[g_idx].add(g_vals)


AGGREGATIONS = ("dense_allreduce", "sparse_allgather")


def wire_words_per_worker(mode: str, length: int, k: int, n_workers: int) -> int:
    """Analytic per-round communication volume (words) — used in benches."""
    if mode == "dense_allreduce":
        return length
    if mode == "sparse_allgather":
        return 2 * k * n_workers
    raise ValueError(f"unknown aggregation {mode!r}")
