"""Thin re-export shim — gradient aggregation lives in :mod:`repro.comm`.

Historically this module held the two inline aggregation patterns
(``dense_allreduce`` psum and ``sparse_allgather`` all_gather+scatter-add).
Those are now the ``repro.comm.collectives`` strategies, parameterized by
the ``repro.comm.codec`` wire codecs, with cost accounting in
``repro.comm.cost``. Import from ``repro.comm`` in new code.
"""
from __future__ import annotations

from repro.comm.collectives import (
    COLLECTIVES,
    allgather_scatter,
    allreduce_dense,
    dense_mean,
    scatter_add_payloads,
)

AGGREGATIONS = tuple(sorted(COLLECTIVES))

__all__ = [
    "AGGREGATIONS",
    "allgather_scatter",
    "allreduce_dense",
    "dense_mean",
    "scatter_add_payloads",
]
