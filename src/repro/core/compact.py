"""Compact (memory-optimal) sparsifier state for the distributed runtime.

The simulator's dense ``SparsifierState`` stores eps, a_prev and s_prev —
3 full J-sized vectors per worker. At framework scale (mixtral: J = 47B,
J/16 per model shard) that is untenable. Observation (ours, beyond paper):
Algorithm 2 only ever reads

  * ``a^{t-1}`` and ``s^{t-1}`` at the k *sent* coordinates (everywhere
    else the likelihood is the constant C), and
  * ``g^{t-1}`` at those same coordinates (the posterior-distortion
    numerator).

So the exact per-worker state is: dense error ``eps [L]`` plus three
k-vectors ``(sent_vals, sent_g, sent_idx)`` — a 3x memory reduction with
bit-identical selection. This module implements Top-k / RegTop-k / cyclic
(coordinated) / none over flat local gradient shards with that layout.

All functions operate on the *local* view inside ``shard_map``:
one (worker × model-shard) flat vector of length L.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import selectors as sel_lib
from repro.core.sparsify import SparsifierConfig


class CompactState(NamedTuple):
    eps: jax.Array  # [L]   dense sparsification error
    sent_vals: jax.Array  # [k]   a^{t-1} at sent coords
    sent_g: jax.Array  # [k]   g^{t-1} (aggregated) at sent coords
    sent_idx: jax.Array  # [k]   int32 coords sent at t-1
    # [k] per-coordinate sender mass den[j] the server divided by at the
    # sent coords (weighting="coordinate"); exactly 1.0 under worker
    # weighting, so omega / sent_w == omega bit-for-bit there.
    sent_w: jax.Array
    t: jax.Array  # []    round counter


def compact_init(length: int, k: int, dtype=jnp.float32) -> CompactState:
    # sent_w starts at 0 (matching the zeros-everywhere state init the
    # runtimes broadcast); compact_select guards it to 1 before dividing,
    # and round 0 scores plain Top-k anyway (t == 0).
    return CompactState(
        eps=jnp.zeros((length,), dtype),
        sent_vals=jnp.zeros((k,), dtype),
        sent_g=jnp.zeros((k,), dtype),
        sent_idx=jnp.zeros((k,), jnp.int32),
        sent_w=jnp.zeros((k,), dtype),
        t=jnp.zeros((), jnp.int32),
    )


def _apply_k_dyn(a, vals, idx, k_dyn, capacity: int):
    """Keep only the first ``k_dyn`` of the descending-sorted payload.

    ``lax.top_k`` (and the bit-identical fused pipeline) returns values in
    descending score order, so masking the tail selects exactly the
    dynamic top-``k_dyn`` — the masked slots keep their real, distinct
    indices with value 0, the same no-op-under-scatter-add convention the
    static path uses for unfilled slots."""
    keep = (jnp.arange(capacity) < k_dyn).astype(vals.dtype)
    return a, vals * keep, idx


def compact_select(
    cfg: SparsifierConfig,
    st: CompactState,
    g: jax.Array,
    k: int,
    *,
    k_dyn: jax.Array | None = None,
    fastpath: str | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Select coordinates. Returns (a, vals [k], idx [k]).

    ``a`` is the accumulated gradient; (vals, idx) the fixed-k payload.

    ``k_dyn`` (optional, *traced* int, ``<= k``) is the adaptive
    controller's per-round k: selection still runs at the static capacity
    ``k`` (payload shapes never change — no retrace), then payload values
    beyond ``k_dyn`` are zeroed. Only the magnitude-scored kinds under the
    ``"exact"`` selector support it; at ``k_dyn == k`` the result is
    bit-for-bit the static path.

    ``fastpath`` routes fusable configs through the Pallas fused
    select→encode pipeline (:mod:`repro.comm.fastpath`): ``"on"``/
    ``"auto"`` fuse when the (kind, selector, shape, f32 state) admits
    it — the result is bit-for-bit identical (a runtime exactness
    certificate falls back to this dense path otherwise; a non-f32 state
    would score in a different precision, so it never fuses) — while
    ``None``/``"off"`` is the historical dense selection. ``"auto"``
    additionally requires a TPU backend and the throughput table's
    blessing, mirroring ``DistConfig.resolved_fastpath``.
    """
    L = g.shape[0]
    if k_dyn is not None and (
        cfg.kind not in ("topk", "regtopk") or cfg.selector != "exact"
    ):
        raise ValueError(
            "dynamic per-round k needs a magnitude-scored fixed-k kind "
            "('topk'/'regtopk') under selector='exact'; got kind="
            f"{cfg.kind!r} selector={cfg.selector!r}"
        )
    if fastpath not in (None, "off"):
        from repro.comm import fastpath as fp

        if fastpath not in fp.FASTPATH_MODES:
            raise ValueError(
                f"unknown fastpath {fastpath!r}; "
                f"available: {fp.FASTPATH_MODES}"
            )
        if (
            st.eps.dtype == jnp.float32
            and fp.config_fusable(cfg)[0]
            and fp.shape_fusable(L, k)[0]
            and (
                fastpath == "on"
                or (
                    fp.backend_supports()
                    and fp.ThroughputTable().prefers_fused(L, k)
                )
            )
        ):
            a, vals, idx = fp.fused_compact_select(cfg, st, g, k)
            if k_dyn is None:
                return a, vals, idx
            return _apply_k_dyn(a, vals, idx, k_dyn, k)
    a = st.eps + g.astype(st.eps.dtype)
    if cfg.kind == "none":
        raise ValueError("'none' bypasses compact_select")
    if cfg.kind == "cyclic":
        # Beyond-paper coordinated round-robin (common across workers):
        # the mask is a pure function of (t, k, L) -> exact cancellation of
        # heterogeneous components (see EXPERIMENTS.md §Beyond).
        start = (st.t * k) % L
        idx = (start + jnp.arange(k)) % L
        return a, a[idx], idx

    amag = jnp.abs(a)
    if cfg.kind == "topk":
        score = amag
    elif cfg.kind == "regtopk":
        # Remark-4 prior exponent: the selection metric is |a|^y * reg. The
        # exponent must be applied *before* the sent-coordinate
        # regularization so sent scores are mag^y * reg, matching
        # RegTopK._score (t == 0 is plain Top-k — Alg. 2 line 2).
        mag = amag if cfg.y == 1.0 else amag**cfg.y
        # dense default: unsent coords carry likelihood C = tanh(Q/mu) -> 1.
        # Under coordinate weighting the server divided each sent coord by
        # its sender mass (sent_w), so this worker's effective omega there
        # was omega / sent_w; worker weighting records sent_w == 1, making
        # the division exact and the path bit-for-bit with the scalar form.
        w_safe = jnp.where(st.sent_w > 0, st.sent_w, 1.0)
        omega_vec = cfg.omega / w_safe
        denom = omega_vec * a[st.sent_idx]
        safe = jnp.where(denom == 0, 1.0, denom)
        delta = (st.sent_g - omega_vec * st.sent_vals) / safe
        reg = jnp.tanh(jnp.abs(1.0 + delta) / cfg.mu)
        sent_score = mag[st.sent_idx] * reg
        score = jnp.where(
            st.t == 0, amag, mag.at[st.sent_idx].set(sent_score)
        )
    else:
        raise ValueError(f"unsupported compact kind {cfg.kind!r}")
    if cfg.selector == "exact":
        _, idx = jax.lax.top_k(score, k)
        # zero scores are never selected (parity with exact_topk_mask):
        # unfilled slots keep their (distinct) top-k index but carry value
        # 0 — a no-op contribution on the wire, and no duplicate indices
        # for the scatter consumers downstream.
        vals = a[idx] * (score[idx] > 0)
        if k_dyn is None:
            return a, vals, idx
        return _apply_k_dyn(a, vals, idx, k_dyn, k)
    if cfg.selector == "threshold":
        mask = sel_lib.threshold_topk_mask(score, k)
        vals, idx = sel_lib.mask_to_payload(mask, a, k)
        return a, vals, idx
    raise ValueError(
        f"compact_select does not support selector {cfg.selector!r}; "
        "available: 'exact', 'threshold'"
    )


def _sent_w_at(
    idx: jax.Array, den: jax.Array | None, dtype
) -> jax.Array:
    """Record the sender mass at the sent coords: ``den[idx]`` under
    coordinate weighting, exactly 1.0 under worker weighting (den=None)."""
    if den is None:
        return jnp.ones(idx.shape, dtype)
    return den[idx].astype(dtype)


def compact_finalize(
    st: CompactState,
    a: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    agg: jax.Array,
    den: jax.Array | None = None,
) -> CompactState:
    """Post-aggregation state update (needs the aggregated gradient to
    record sent_g for the next round's posterior distortion; ``den`` is
    the per-coordinate sender mass under coordinate weighting).

    ``eps' = a - scatter_add(vals, idx)``: exactly zero at genuinely sent
    coordinates (``vals == a[idx]`` there, and ``x - x == 0`` in floats),
    and — unlike an ``a.at[idx].set(0)`` — it keeps the full accumulated
    value at any *padding* slot (value 0 riding a real index, produced
    when fewer than k coordinates have nonzero score, or by
    ``mask_to_payload``'s (0, 0) pairs), so an unsent coordinate is never
    silently dropped from error feedback."""
    sent_dense = jnp.zeros_like(a).at[idx].add(vals)
    eps_new = a - sent_dense
    return CompactState(
        eps=eps_new,
        sent_vals=vals,
        sent_g=agg[idx].astype(vals.dtype),
        sent_idx=idx,
        sent_w=_sent_w_at(idx, den, st.sent_w.dtype),
        t=st.t + 1,
    )


def compact_finalize_sent(
    st: CompactState,
    a: jax.Array,
    sent_vals: jax.Array,
    sent_idx: jax.Array,
    sent_dense: jax.Array,
    agg: jax.Array,
    den: jax.Array | None = None,
) -> CompactState:
    """Codec-aware finalize: error feedback against what was *actually*
    transmitted. ``sent_dense`` is the decoded wire contribution, so
    ``eps' = a - sent_dense`` keeps any codec loss (e.g. ``coo_q8``
    quantization residual) in the accumulator; ``sent_vals``/``sent_idx``
    are the decoded payload — what the server saw — which is what RegTop-k's
    posterior distortion must condition on next round. Identical to
    :func:`compact_finalize` for lossless codecs."""
    return CompactState(
        eps=(a - sent_dense.astype(a.dtype)),
        sent_vals=sent_vals.astype(st.sent_vals.dtype),
        sent_g=agg[sent_idx].astype(st.sent_g.dtype),
        sent_idx=sent_idx,
        sent_w=_sent_w_at(sent_idx, den, st.sent_w.dtype),
        t=st.t + 1,
    )


# ---------------------------------------------------------------------------
# dense-state equivalence oracle (used by tests)
# ---------------------------------------------------------------------------
def reference_step(
    cfg: SparsifierConfig,
    st: CompactState,
    g: jax.Array,
    g_prev_dense: jax.Array,
    k: int,
    omega_prev: jax.Array | None = None,
):
    """Reconstruct the dense-state step for equivalence testing.

    ``omega_prev`` is the dense ``[L]`` sender mass under coordinate
    weighting (what the compact path records at the sent coords as
    ``sent_w``); None is the scalar worker-weighting oracle."""
    from repro.core.sparsify import SparsifierState, make_sparsifier

    L = g.shape[0]
    s_prev = jnp.zeros((L,)).at[st.sent_idx].set(
        jnp.where(st.t > 0, 1.0, 0.0)
    )
    a_prev = jnp.zeros((L,)).at[st.sent_idx].set(st.sent_vals)
    # test oracle: rebuilding the dense state from the compact layout is
    # the point of this function.
    dense = SparsifierState(  # reprolint: disable=RPL106
        eps=st.eps, a_prev=a_prev, s_prev=s_prev, t=st.t
    )
    sp = make_sparsifier(dataclasses.replace(cfg, sparsity=k / L, selector="exact"))
    return sp.step(dense, g, g_prev_dense, omega_prev=omega_prev)
