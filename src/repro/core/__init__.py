"""RegTop-k core: Bayesian gradient sparsification (paper's contribution)."""
from repro.core.aggregate import (
    AGGREGATIONS,
    allgather_scatter,
    allreduce_dense,
    dense_mean,
    scatter_add_payloads,
)
from repro.core.selectors import (
    SELECTORS,
    exact_topk_mask,
    exact_topk_mask_dynamic,
    fixed_k_payload,
    get_selector,
    mask_to_payload,
    sparsity_to_k,
    threshold_topk_mask,
)
from repro.core.simulator import DistributedSim, SimState
from repro.core.sparsify import (
    KINDS,
    HardThreshold,
    NoneSparsifier,
    RegTopK,
    Sparsifier,
    SparsifierConfig,
    SparsifierState,
    TopK,
    make_sparsifier,
)

__all__ = [
    "AGGREGATIONS",
    "DistributedSim",
    "HardThreshold",
    "KINDS",
    "NoneSparsifier",
    "RegTopK",
    "SELECTORS",
    "SimState",
    "Sparsifier",
    "SparsifierConfig",
    "SparsifierState",
    "TopK",
    "allgather_scatter",
    "allreduce_dense",
    "dense_mean",
    "exact_topk_mask",
    "exact_topk_mask_dynamic",
    "fixed_k_payload",
    "get_selector",
    "make_sparsifier",
    "mask_to_payload",
    "scatter_add_payloads",
    "sparsity_to_k",
    "threshold_topk_mask",
]
