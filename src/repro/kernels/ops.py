"""jit'd public wrappers around the Pallas kernels.

Handles the layout contract (flatten → pad → [rows, 1024] tiles) and
selects interpret mode automatically off-TPU, so the same call sites run
on CPU (validation) and TPU (deployment).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import (
    block_topk as _bt,
    fused_encode as _fe,
    regtopk_score as _rs,
    threshold_topk as _tt,
)

LANES = _rs.LANES
SUBLANES = _rs.SUBLANES
TILE = LANES * SUBLANES


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tile(x: jax.Array) -> Tuple[jax.Array, int]:
    """Flatten + zero-pad to [rows, LANES] with rows % 8 == 0."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


@functools.partial(
    jax.jit, static_argnames=("omega", "mu", "q", "y", "interpret")
)
def regtopk_score(
    a, a_prev, s_prev, g_prev, *, omega, mu, q=1e9, y=1.0, interpret=None
):
    """Fused Alg.2 score over an arbitrary-shape gradient tensor."""
    interp = (not _on_tpu()) if interpret is None else interpret
    at, n = _tile(a.astype(jnp.float32))
    pt, _ = _tile(a_prev.astype(jnp.float32))
    st, _ = _tile(s_prev.astype(jnp.float32))
    gt, _ = _tile(g_prev.astype(jnp.float32))
    out = _rs.regtopk_score(
        at, pt, st, gt, omega=omega, mu=mu, q=q, y=y, interpret=interp
    )
    return out.reshape(-1)[:n].reshape(a.shape)


@functools.partial(
    jax.jit,
    static_argnames=("k", "m", "omega", "mu", "q", "y", "interpret"),
)
def fused_select_encode(
    a, a_prev, s_prev, g_prev, *, k, omega, mu, q=1e9, y=1.0, m=16,
    interpret=None,
):
    """Fused score→select→payload over an arbitrary-shape gradient tensor.

    Returns ``(vals [k], idx [k], ok)``: the compact wire payload straight
    from the score-kernel registers, plus the exactness certificate (see
    ``fused_encode.select_from_candidates``). ``ok`` guards bit-for-bit
    equality with ``lax.top_k`` over the dense score — callers
    ``lax.cond`` to the dense path when it is False. Zero-padding from the
    layout contract scores 0 and never passes the certificate."""
    interp = (not _on_tpu()) if interpret is None else interpret
    at, n = _tile(a.astype(jnp.float32))
    pt, _ = _tile(a_prev.astype(jnp.float32))
    st, _ = _tile(s_prev.astype(jnp.float32))
    gt, _ = _tile(g_prev.astype(jnp.float32))
    cs, cv, ci = _fe.fused_candidates(
        at, pt, st, gt, omega=omega, mu=mu, q=q, y=y, m=m, interpret=interp
    )
    return _fe.select_from_candidates(cs, cv, ci, k)


@functools.partial(jax.jit, static_argnames=("k", "n_iters", "interpret"))
def threshold_topk_mask(score, k: int, *, n_iters=24, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    st, n = _tile(score.astype(jnp.float32))
    mask = _tt.threshold_topk_mask(st, k, n_iters=n_iters, interpret=interp)
    return mask.reshape(-1)[:n].reshape(score.shape)


@functools.partial(jax.jit, static_argnames=("k", "m", "interpret"))
def hierarchical_topk(score, k: int, m: int = 8, *, interpret=None):
    """(vals [k], flat idx [k]) — per-block candidates + exact reduce."""
    interp = (not _on_tpu()) if interpret is None else interpret
    st, n = _tile(score.astype(jnp.float32))
    return _bt.hierarchical_topk(st, k, m=m, interpret=interp)
