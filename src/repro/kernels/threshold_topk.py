"""Threshold-count kernel for sort-free top-k (Pallas TPU).

Exact ``lax.top_k`` over a J-sized score is sort-bound (O(J log J), poor
VPU utilization). Gradient-compression systems (DGC, ScaleCom) instead
find a *threshold*: this kernel computes, in one streaming pass per
bisection step,

    count(tau)  = #{ j : score[j] >= tau }        (for the bisection)
    blockmax    = max over the whole vector       (for the initial bracket)

The grid walks (8, 1024) VMEM tiles; scalar results accumulate into a
(1, 1) output across sequential grid steps (TPU grid execution is
sequential, so read-modify-write accumulation is well-defined).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)


def _count_kernel(tau_ref, score_ref, count_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    tau = tau_ref[0, 0]
    c = jnp.sum((score_ref[...] >= tau).astype(jnp.int32))
    count_ref[0, 0] += c


def _max_kernel(score_ref, max_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)

    m = jnp.max(score_ref[...])
    max_ref[0, 0] = jnp.maximum(max_ref[0, 0], m)


def count_above(
    score: jax.Array, tau: jax.Array, *, interpret: bool = False
) -> jax.Array:
    rows, lanes = score.shape
    grid = (rows // SUBLANES,)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(tau.reshape(1, 1), score)[0, 0]


def global_max(score: jax.Array, *, interpret: bool = False) -> jax.Array:
    rows, lanes = score.shape
    grid = (rows // SUBLANES,)
    return pl.pallas_call(
        _max_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(BLOCK, lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(score)[0, 0]


def threshold_topk_mask(
    score: jax.Array,
    k: int,
    *,
    n_iters: int = 24,
    interpret: bool = False,
) -> jax.Array:
    """~k-cardinality mask via kernel-accelerated bisection.

    ``score`` [rows, 1024] non-negative. Matches
    ``repro.core.selectors.threshold_topk_mask`` semantics (mask contains
    the exact top-k, possibly a few extra on ties/unconverged brackets).
    """
    hi0 = global_max(score, interpret=interpret)
    lo0 = jnp.zeros_like(hi0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        c = count_above(score, mid, interpret=interpret)
        ok = c >= k
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    # zero scores carry no gradient and are never selected — keeps the
    # all-zero-score round from collapsing to an all-ones mask (matches
    # the selectors.threshold_topk_mask fix).
    return ((score >= lo) & (score > 0)).astype(score.dtype)
