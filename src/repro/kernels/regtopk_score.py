"""Fused RegTop-k score kernel (Pallas TPU).

The selection metric (paper Alg. 2 lines 8–9)

    Delta = s_prev * (g_prev - omega * a_prev) / (omega * a) + Q (1 - s_prev)
    score = |a|^y * tanh(|1 + Delta| / mu)     (y = 1 fast path skips the pow)

is a 4-input elementwise chain over the J-sized gradient — purely
memory-bound. Unfused, XLA:CPU-style execution would stream ~9 J-sized
intermediates through HBM; this kernel makes one pass: 4 reads + 1 write
per element, VMEM-tiled in (8, 1024) float32 blocks (8x128-lane aligned).

Layout contract: callers flatten the gradient to [rows, 1024] (padding the
tail with zeros — zero ``a`` scores zero, so padding never wins selection).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)


def score_chain(a, a_prev, s_prev, g_prev, *, omega, mu, q, y):
    """The Alg. 2 selection-metric op chain, in-register.

    Shared by :func:`_score_kernel` and the fused select→encode kernel
    (``fused_encode._fused_kernel``): the fused pipeline's bit-for-bit
    equivalence argument depends on both executing this *exact* op
    sequence, so any numerics change must live here, once."""
    denom = omega * a
    safe = jnp.where(denom == 0.0, 1.0, denom)
    delta_sent = (g_prev - omega * a_prev) / safe
    delta = jnp.where(s_prev > 0.0, delta_sent, q)
    reg = jnp.tanh(jnp.abs(1.0 + delta) / mu)
    mag = jnp.abs(a)
    if y != 1.0:  # compile-time constant: the y == 1 fast path skips the pow
        mag = mag**y
    return mag * reg


def _score_kernel(
    a_ref, a_prev_ref, s_prev_ref, g_prev_ref, out_ref, *, omega, mu, q, y
):
    out_ref[...] = score_chain(
        a_ref[...], a_prev_ref[...], s_prev_ref[...], g_prev_ref[...],
        omega=omega, mu=mu, q=q, y=y,
    )


def regtopk_score(
    a: jax.Array,
    a_prev: jax.Array,
    s_prev: jax.Array,
    g_prev: jax.Array,
    *,
    omega: float,
    mu: float,
    q: float = 1e9,
    y: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    """All inputs [rows, 1024] float32; returns the score, same shape.

    ``y`` is the Remark-4 prior exponent (compile-time constant; the
    selection metric is ``|a|^y * tanh(|1 + Delta| / mu)``, matching
    ``RegTopK._score``).
    """
    rows, lanes = a.shape
    if lanes != LANES:
        raise ValueError(f"expected lane dim {LANES}, got {lanes}")
    if rows % SUBLANES:
        raise ValueError(f"rows must be a multiple of {SUBLANES}")
    grid = (rows // SUBLANES,)
    spec = pl.BlockSpec(BLOCK, lambda i: (i, 0))
    kernel = functools.partial(_score_kernel, omega=omega, mu=mu, q=q, y=y)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), a.dtype),
        interpret=interpret,
    )(a, a_prev, s_prev, g_prev)
