"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def regtopk_score_ref(a, a_prev, s_prev, g_prev, *, omega, mu, q=1e9, y=1.0):
    denom = omega * a
    safe = jnp.where(denom == 0.0, 1.0, denom)
    delta_sent = (g_prev - omega * a_prev) / safe
    delta = jnp.where(s_prev > 0.0, delta_sent, q)
    mag = jnp.abs(a)
    if y != 1.0:
        mag = mag**y
    return mag * jnp.tanh(jnp.abs(1.0 + delta) / mu)


def count_above_ref(score, tau):
    return jnp.sum((score >= tau).astype(jnp.int32))


def global_max_ref(score):
    return jnp.max(score)


def threshold_topk_mask_ref(score, k, n_iters=24):
    hi0 = jnp.max(score)
    lo0 = jnp.zeros_like(hi0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = jnp.sum(score >= mid) >= k
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    return ((score >= lo) & (score > 0)).astype(score.dtype)


def fused_select_encode_ref(
    a, a_prev, s_prev, g_prev, k, *, omega, mu, q=1e9, y=1.0
) -> Tuple[jax.Array, jax.Array]:
    """Unfused oracle for the fused select→encode pipeline: dense score,
    ``lax.top_k`` selection, payload gather with zero-score slots zeroed —
    exactly the ``compact.compact_select`` exact-selector semantics the
    fused path must reproduce bit-for-bit."""
    score = regtopk_score_ref(
        a, a_prev, s_prev, g_prev, omega=omega, mu=mu, q=q, y=y
    )
    _, idx = jax.lax.top_k(score, k)
    return a[idx] * (score[idx] > 0), idx


def block_topk_candidates_ref(score, m=8) -> Tuple[jax.Array, jax.Array]:
    rows, lanes = score.shape
    nblk = rows // 8
    s = score.reshape(nblk, 8 * lanes).astype(jnp.float32)
    vals, local = jax.lax.top_k(s, m)  # ties: lowest index first (stable)
    base = (jnp.arange(nblk) * 8 * lanes)[:, None]
    return vals, (base + local).astype(jnp.int32)
