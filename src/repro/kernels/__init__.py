"""Pallas TPU kernels for the sparsification hot spots.

* ``regtopk_score``  — fused Alg.2 selection metric (memory-bound chain)
* ``threshold_topk`` — sort-free top-k via streaming count bisection
* ``block_topk``     — per-tile top-m candidates for hierarchical top-k
* ``fused_encode``   — one-pass score→select→payload pipeline: per-tile
  candidates straight from score registers, host-side compaction to the
  compact ``(idx, val)`` wire payload (``repro.comm.fastpath`` policy)

``ops`` holds the jit'd public wrappers (auto interpret-mode off-TPU);
``ref`` the pure-jnp oracles every kernel is allclose-tested against.
"""
from repro.kernels import (
    block_topk,
    fused_encode,
    ops,
    ref,
    regtopk_score,
    threshold_topk,
)
