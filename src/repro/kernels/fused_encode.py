"""Fused select→encode pipeline kernel (Pallas TPU).

The unfused hot path materializes three J-sized intermediates between the
score and the wire: the dense score (written by ``regtopk_score``, re-read
by the selector), the dense mask, and the dense masked gradient — plus a
separate gather of ``a[idx]`` for the payload. This kernel collapses the
chain into **one pass over the gradient leaf**:

    per (8, 1024) tile:  score = |a|^y * tanh(|1 + Delta| / mu)   (registers)
                         m rounds of masked max over the tile's score
                         → (score, a-value, flat index) candidate triples

The score never leaves VMEM: each tile emits its top-``m`` candidates
directly from the score-kernel registers — 4 J-sized reads and a
``(J/8192)·m``-triple write, versus the unfused 4 reads + 1 J-write
(score) + 1 J-read (selector) + gather. The host then runs the cheap
compaction: an exact top-k over the ~1000x smaller candidate set, whose
k-th value is the selection threshold tau, produces the compact
``(idx, val)`` wire payload — codec epilogues (e.g. ``coo_q8``'s
symmetric int8 quantization) operate on those k registers directly
(``Codec.encode_fused``).

Exactness: the candidate set provably contains the global top-k whenever
no tile hides more than ``m`` coordinates scoring at-or-above the k-th
selected value. :func:`select_from_candidates` returns an ``ok`` flag
implementing exactly that certificate (conservative under ties); callers
``lax.cond`` to the unfused path when it fails, so the pipeline is
bit-for-bit equivalent to dense selection *unconditionally* — the
certificate only decides which path computed the answer. See
``repro.comm.fastpath`` for the policy layer and
``docs/comm.md#the-fused-fastpath`` for the fusability matrix.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.regtopk_score import score_chain

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)
TILE = SUBLANES * LANES


def _fused_kernel(
    a_ref, a_prev_ref, s_prev_ref, g_prev_ref,
    cs_ref, cv_ref, ci_ref, *, omega, mu, q, y, m,
):
    i = pl.program_id(0)
    a = a_ref[...]
    # --- scoring stage: the one shared op chain (regtopk_score.score_chain
    # — bit-for-bit parity with the unfused score is what makes the fused
    # payload provably equal to the unfused one).
    score = score_chain(
        a, a_prev_ref[...], s_prev_ref[...], g_prev_ref[...],
        omega=omega, mu=mu, q=q, y=y,
    )
    # --- selection stage: per-tile top-m by m rounds of masked max (the
    # block_topk scan), emitting the payload *value* a alongside the score
    # so no post-hoc gather over the dense gradient is needed.
    rowi = jax.lax.broadcasted_iota(jnp.int32, BLOCK, 0)
    colj = jax.lax.broadcasted_iota(jnp.int32, BLOCK, 1)
    flat = (i * SUBLANES + rowi) * LANES + colj
    s = score
    for r in range(m):  # static tiny unroll
        cur = jnp.max(s)
        ismax = s == cur
        # first-match tie break: lowest flat index among maxima (matches
        # lax.top_k's stable ordering for the equivalence proof)
        cand = jnp.min(jnp.where(ismax, flat, jnp.iinfo(jnp.int32).max))
        onehot = flat == cand
        cs_ref[0, r] = cur
        cv_ref[0, r] = jnp.sum(jnp.where(onehot, a, 0.0))
        ci_ref[0, r] = cand
        s = jnp.where(onehot, -jnp.inf, s)


def fused_candidates(
    a: jax.Array,
    a_prev: jax.Array,
    s_prev: jax.Array,
    g_prev: jax.Array,
    *,
    omega: float,
    mu: float,
    q: float = 1e9,
    y: float = 1.0,
    m: int = 16,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """All inputs [rows, 1024] float32. Returns per-tile candidate triples
    ``(scores [nblk, m], values [nblk, m], flat idx [nblk, m])`` where
    ``nblk = rows // 8`` — the score is computed and consumed in-register,
    never written back dense."""
    rows, lanes = a.shape
    if lanes != LANES:
        raise ValueError(f"expected lane dim {LANES}, got {lanes}")
    if rows % SUBLANES:
        raise ValueError(f"rows must be a multiple of {SUBLANES}")
    nblk = rows // SUBLANES
    spec = pl.BlockSpec(BLOCK, lambda i: (i, 0))
    cand = pl.BlockSpec((1, m), lambda i: (i, 0))
    kernel = functools.partial(
        _fused_kernel, omega=omega, mu=mu, q=q, y=y, m=m
    )
    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[spec, spec, spec, spec],
        out_specs=(cand, cand, cand),
        out_shape=(
            jax.ShapeDtypeStruct((nblk, m), jnp.float32),
            jax.ShapeDtypeStruct((nblk, m), jnp.float32),
            jax.ShapeDtypeStruct((nblk, m), jnp.int32),
        ),
        interpret=interpret,
    )(a, a_prev, s_prev, g_prev)


def select_from_candidates(
    cand_score: jax.Array,
    cand_val: jax.Array,
    cand_idx: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compact the ``[nblk, m]`` candidate triples into the fixed-k payload.

    Returns ``(vals [k], idx [k], ok)``. The top-k over the flattened
    candidate scores doubles as the threshold selection: the k-th selected
    score is the selection threshold tau, and candidate order (tile-major,
    rank-minor) equals flat-index order under ties, so the result is
    bit-for-bit ``lax.top_k`` over the dense score *provided* the
    exactness certificate ``ok`` holds:

        ok  :=  every tile's m-th (smallest kept) candidate  <  tau

    If a tile's m-th candidate reaches tau, coordinates hidden below its
    candidate budget could score at-or-above tau (or tie it), so the
    caller must fall back to dense selection. ``tau == 0`` (selection ran
    out of positive scores) always fails the certificate — zero scores
    are never selected on the fast path, which also keeps zero-padding
    flat indices (>= the true length) out of the payload.

    Single-tile refinement: with one tile the candidates *are* the exact
    top-m (m rounds of masked max), tie order included, so any positive
    tau certifies exactness — a hidden tie at tau necessarily carries a
    higher flat index than every selected tie (the masked max consumes
    equal values lowest-index first), which is precisely ``lax.top_k``'s
    ordering. Across tiles that argument breaks (a hidden tie in an early
    tile would outrank a selected tie in a later one), hence the strict
    inequality there."""
    nblk, m = cand_score.shape
    k = int(k)
    if k > nblk * m:
        raise ValueError(
            f"k={k} exceeds the candidate budget {nblk}x{m}; the caller "
            "should have routed this leaf to the unfused path"
        )
    top_s, pos = jax.lax.top_k(cand_score.reshape(-1), k)
    tau = top_s[k - 1]
    vals = cand_val.reshape(-1)[pos] * (top_s > 0)
    idx = cand_idx.reshape(-1)[pos]
    if nblk == 1:
        ok = tau > 0
    else:
        ok = jnp.all(cand_score[:, m - 1] < tau)
    return vals, idx, ok
