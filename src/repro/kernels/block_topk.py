"""Per-block top-m candidate extraction (Pallas TPU).

Hierarchical top-k: each (8, 1024) tile emits its top-m candidates
(values + flat indices) by m rounds of masked max — VPU-only, no sort.
The host then runs exact top-k over the (rows/8)*m candidates, a ~1000x
smaller problem. Exact whenever every tile contributes <= m winners
(guaranteed for k <= m; overwhelmingly likely for uniform-ish score mass),
and the selection-quality benchmark quantifies the miss rate otherwise.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)


def _block_topm_kernel(score_ref, vals_ref, idx_ref, *, m):
    i = pl.program_id(0)
    s = score_ref[...].astype(jnp.float32)  # [8, 1024]
    rowi = jax.lax.broadcasted_iota(jnp.int32, BLOCK, 0)
    colj = jax.lax.broadcasted_iota(jnp.int32, BLOCK, 1)
    flat = (i * SUBLANES + rowi) * LANES + colj  # global flat index
    for r in range(m):  # static tiny unroll
        cur = jnp.max(s)
        ismax = s == cur
        # first-match tie break: lowest flat index among maxima
        cand_idx = jnp.min(jnp.where(ismax, flat, jnp.iinfo(jnp.int32).max))
        vals_ref[0, r] = cur
        idx_ref[0, r] = cand_idx
        s = jnp.where(flat == cand_idx, -jnp.inf, s)


def block_topk_candidates(
    score: jax.Array, m: int = 8, *, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """score [rows, 1024] -> (vals [rows//8, m], flat idx [rows//8, m])."""
    rows, lanes = score.shape
    nblk = rows // SUBLANES
    grid = (nblk,)
    kernel = functools.partial(_block_topm_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(BLOCK, lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nblk, m), jnp.float32),
            jax.ShapeDtypeStruct((nblk, m), jnp.int32),
        ),
        interpret=interpret,
    )(score)


def hierarchical_topk(
    score: jax.Array, k: int, m: int = 8, *, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Approximate global top-k from per-block candidates.

    Returns (vals [k], flat_idx [k]) sorted descending by value.
    """
    vals, idx = block_topk_candidates(score, m=m, interpret=interpret)
    fv, fi = vals.reshape(-1), idx.reshape(-1)
    top_v, pos = jax.lax.top_k(fv, k)
    return top_v, fi[pos]
