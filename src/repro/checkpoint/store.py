"""Checkpoint store: arrays as .npz, tree structure + metadata as msgpack.

Sharding-aware in the practical sense: arrays are gathered to host
(``jax.device_get``) on save, and on restore the caller passes target
shardings (or a donor pytree) so parameters land back on the mesh with
``jax.device_put``. Works for params, optimizer state, sparsifier state,
and the data-pipeline step counter alike — anything pytree.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(path: str, tree: Any, *, metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat, treedef = _flatten_with_paths(tree)
    arrays = {}
    kinds = []
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays[f"a{i}"] = arr.view(np.uint16)
            kinds.append("bfloat16")
        else:
            arrays[f"a{i}"] = arr
            kinds.append(str(arr.dtype))
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "kinds": kinds,
        "user": metadata or {},
    }
    with open(os.path.join(path, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    # store the treedef via example structure (for exact reconstruction we
    # rely on a donor tree at restore; the string form is for inspection)


def restore(path: str, donor: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``donor`` (shapes/dtypes validated)."""
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_donor, treedef = jax.tree.flatten(donor)
    if meta["n_leaves"] != len(flat_donor):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, donor has "
            f"{len(flat_donor)}"
        )
    out = []
    for i, (d, kind) in enumerate(zip(flat_donor, meta["kinds"])):
        arr = data[f"a{i}"]
        if kind == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        want = jax.ShapeDtypeStruct(
            getattr(d, "shape", np.shape(d)), getattr(d, "dtype", None)
        )
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != donor {want.shape}"
            )
        out.append(jnp.asarray(arr, dtype=want.dtype))
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def metadata(path: str) -> dict:
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())["user"]
