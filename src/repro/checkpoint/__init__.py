"""Pytree checkpointing (npz payload + msgpack treedef)."""
from repro.checkpoint.store import restore, save

__all__ = ["save", "restore"]
