"""repro — RegTop-k (Bayesian gradient sparsification) as a JAX framework.

Subpackages: core (sparsifiers + distributed runtime), comm (wire codecs,
collective strategies, cost accounting), nn, models, configs, optim, data,
checkpoint, launch, kernels. See README.md.
"""
__version__ = "0.1.0"
