"""Decoder-only transformer LM — dense GQA, MoE, and VLM-prefix variants.

Covers assigned archs: qwen2.5-3b, chatglm3-6b, granite-3-8b,
phi3-medium-14b (dense); mixtral-8x7b, deepseek-moe-16b (moe);
internvl2-1b (vlm — language decoder consuming stub patch embeddings).

Layer stack is scan-over-stacked-params (compile time independent of
depth); attention is dense for training (remat at block level), chunked
online-softmax for long prefill, and cache-based for decode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn import layers as L, moe as M

Params = Dict[str, Any]


def _norm(cfg: ModelConfig):
    if cfg.norm == "rmsnorm":
        return L.rmsnorm_init, functools.partial(L.rmsnorm, eps=cfg.norm_eps)
    return L.layernorm_init, functools.partial(L.layernorm, eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ninit, _ = _norm(cfg)
    k1, k2 = jax.random.split(key)
    attn_p, attn_a = L.attn_init(
        k1,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.hd,
        qkv_bias=cfg.qkv_bias,
        dtype=cfg.jdtype,
        pad_to=cfg.pad_heads,
    )
    n1p, n1a = ninit(cfg.d_model, cfg.jdtype)
    n2p, n2a = ninit(cfg.d_model, cfg.jdtype)
    if cfg.is_moe:
        mlp_p, mlp_a = M.moe_init(
            k2,
            cfg.d_model,
            cfg.d_ff,
            cfg.n_experts,
            n_shared=cfg.n_shared_experts,
            shared_d_ff=cfg.moe_shared_d_ff,
            parallelism=cfg.moe_parallelism,
            dtype=cfg.jdtype,
        )
    else:
        mlp_p, mlp_a = L.mlp_init(
            k2, cfg.d_model, cfg.d_ff, act=cfg.act, dtype=cfg.jdtype
        )
    p = {"attn": attn_p, "mlp": mlp_p, "norm1": n1p, "norm2": n2p}
    a = {"attn": attn_a, "mlp": mlp_a, "norm1": n1a, "norm2": n2a}
    return p, a


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    keys = jax.random.split(key, 4)
    emb_p, emb_a = L.embed_init(
        keys[0], cfg.padded_vocab, cfg.d_model, dtype=cfg.jdtype
    )
    lkeys = jax.random.split(keys[1], cfg.n_layers)
    layers_p = jax.vmap(lambda k: _layer_init(k, cfg)[0])(lkeys)
    _, layer_a = _layer_init(keys[1], cfg)
    layers_a = jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        layer_a,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )
    ninit, _ = _norm(cfg)
    fn_p, fn_a = ninit(cfg.d_model, cfg.jdtype)
    p = {"embed": emb_p, "layers": layers_p, "final_norm": fn_p}
    a = {"embed": emb_a, "layers": layers_a, "final_norm": fn_a}
    if cfg.family == "vlm":
        proj_p, proj_a = L.linear_init(
            keys[2], cfg.vision_dim, cfg.d_model, None, "embed",
            bias=True, dtype=cfg.jdtype,
        )
        p["vision_proj"] = proj_p
        a["vision_proj"] = proj_a
    return p, a


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _block(
    lp: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    mode: str,  # "dense" | "chunked"
) -> Tuple[jax.Array, jax.Array]:
    _, norm = _norm(cfg)
    h = norm(lp["norm1"], x)
    q, k, v = L.attn_qkv(lp["attn"], h)
    q = L.rope(q, positions, base=cfg.rope_base, fraction=cfg.rope_fraction)
    k = L.rope(k, positions, base=cfg.rope_base, fraction=cfg.rope_fraction)
    if mode == "chunked":
        ctx = L.attention_chunked(
            q, k, v, causal=True, window=cfg.sliding_window,
            block=cfg.attn_block,
        )
    else:
        ctx = L.attention_dense(
            q, k, v, causal=True, window=cfg.sliding_window
        )
    x = x + L.attn_out(lp["attn"], ctx)
    h = norm(lp["norm2"], x)
    if cfg.is_moe:
        y, aux = M.moe_apply(
            lp["mlp"], h, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor,
            group_size=cfg.moe_group_size,
            dispatch=cfg.moe_dispatch,
        )
    else:
        y, aux = L.mlp(lp["mlp"], h, act=cfg.act), jnp.zeros((), jnp.float32)
    return x + y, aux


def _stack(params_layers, x, cfg: ModelConfig, positions, mode: str):
    body = functools.partial(_block, cfg=cfg, positions=positions, mode=mode)

    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = None

    def scan_fn(carry, lp):
        x, aux = carry
        fn = jax.checkpoint(body, policy=policy) if cfg.remat else body
        x, a = fn(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)), params_layers
    )
    return x, aux / cfg.n_layers


# ---------------------------------------------------------------------------
# training forward / loss
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """Returns (x [B, S', E], positions [S'])."""
    x = L.embed(params["embed"], batch["tokens"], cfg.jdtype)
    if cfg.family == "vlm":
        vis = L.linear(params["vision_proj"], batch["patches"].astype(cfg.jdtype))
        x = jnp.concatenate([vis, x], axis=1)
    positions = jnp.arange(x.shape[1])
    return x, positions


def forward(params, cfg: ModelConfig, batch, *, mode: str = "dense"):
    """Logits over the token positions (VLM prefix stripped)."""
    x, positions = _embed_inputs(params, cfg, batch)
    x, aux = _stack(params["layers"], x, cfg, positions, mode)
    _, norm = _norm(cfg)
    x = norm(params["final_norm"], x)
    if cfg.family == "vlm":
        x = x[:, -batch["tokens"].shape[1]:]
    logits = L.unembed(params["embed"], x)
    return logits, aux


def mask_pad_logits(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Remove the vocab-padding rows from the softmax support."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    bad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
    return jnp.where(bad, jnp.asarray(L.NEG_INF, logits.dtype), logits)


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch)
    logits = mask_pad_logits(logits.astype(jnp.float32), cfg)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + cfg.aux_loss_coef * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------
def cache_slots(cfg: ModelConfig, max_len: int) -> int:
    """Sliding-window archs keep a ring buffer of ``window`` slots — this is
    what makes long_500k decode feasible for mixtral (cache = 4096 slots,
    not 524288)."""
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    slots = cache_slots(cfg, max_len)
    kv = jnp.zeros(
        (cfg.n_layers, batch, slots, cfg.eff_kv_heads, cfg.hd), cfg.jdtype
    )
    return {"k": kv, "v": kv, "pos": jnp.zeros((), jnp.int32)}


def cache_axes(cfg: ModelConfig) -> Dict:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "pos": ()}


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens: jax.Array):
    """tokens [B, 1]; returns (logits [B, 1, V], new cache).

    The KV cache is a ring buffer when sliding-window attention is on:
    writes go to ``pos % slots`` and all filled slots attend (attention is
    permutation-invariant over the KV set, and keys carry absolute RoPE)."""
    x = L.embed(params["embed"], tokens, cfg.jdtype)
    pos = cache["pos"]
    slots = cache["k"].shape[2]
    write_at = pos % slots if cfg.sliding_window else pos
    filled = jnp.minimum(pos + 1, slots)
    positions = pos[None, None] + jnp.zeros((1, 1), jnp.int32)
    _, norm = _norm(cfg)

    def body(carry, lp_and_cache):
        x = carry
        lp, kc, vc = lp_and_cache
        h = norm(lp["norm1"], x)
        q, k, v = L.attn_qkv(lp["attn"], h)
        q = L.rope(q, positions, base=cfg.rope_base, fraction=cfg.rope_fraction)
        k = L.rope(k, positions, base=cfg.rope_base, fraction=cfg.rope_fraction)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, write_at, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, write_at, axis=1)
        ctx = L.attention_decode(q, kc, vc, filled, window=None)
        x = x + L.attn_out(lp["attn"], ctx)
        h = norm(lp["norm2"], x)
        if cfg.is_moe:
            y, _ = M.moe_apply(
                lp["mlp"], h, top_k=cfg.moe_top_k,
                capacity_factor=cfg.capacity_factor,
                group_size=cfg.moe_group_size,
                no_drop=True,  # serving never drops tokens
            )
        else:
            y = L.mlp(lp["mlp"], h, act=cfg.act)
        return x + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = norm(params["final_norm"], x)
    logits = mask_pad_logits(L.unembed(params["embed"], x), cfg)
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch) -> jax.Array:
    """Prefill logits (chunked attention; no cache materialization here —
    the decode benchmarks build the cache via init_cache + dry-run specs)."""
    logits, _ = forward(params, cfg, batch, mode="chunked")
    return logits
