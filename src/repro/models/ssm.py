"""SSM and hybrid models: mamba2-780m (pure SSD) and zamba2-7b (hybrid).

zamba2 structure (arXiv:2411.15242, adapted): n_layers total blocks; a
single *shared* attention+MLP block (one parameter set) is applied every
``attn_every`` blocks, mamba2 blocks elsewhere. We realize the 81-block
stack as ``n_groups`` super-blocks of (attn_every-1 mamba + shared attn),
plus trailing mamba blocks — scanned, so compile time stays depth-free.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import mask_pad_logits
from repro.nn import layers as L, ssd

Params = Dict[str, Any]


def _plan(cfg: ModelConfig) -> Tuple[int, int, int]:
    """Returns (n_groups, mamba_per_group, trailing_mamba)."""
    if cfg.family != "hybrid":
        return 0, 0, cfg.n_layers
    per = cfg.attn_every  # group = (per-1) mamba + 1 shared attn
    n_groups = cfg.n_layers // per
    trailing = cfg.n_layers - n_groups * per
    return n_groups, per - 1, trailing


def _mamba_init(key, cfg: ModelConfig):
    p, a = ssd.ssd_init(
        key,
        cfg.d_model,
        d_inner=cfg.d_inner,
        headdim=cfg.ssm_headdim,
        d_state=cfg.ssm_state,
        dtype=cfg.jdtype,
    )
    np_, na_ = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    return {"mixer": p, "norm": np_}, {"mixer": a, "norm": na_}


def _attn_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    ap, aa = L.attn_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype=cfg.jdtype
    )
    mp, ma = L.mlp_init(k2, cfg.d_model, cfg.d_ff, act=cfg.act, dtype=cfg.jdtype)
    n1p, n1a = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    n2p, n2a = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    return (
        {"attn": ap, "mlp": mp, "norm1": n1p, "norm2": n2p},
        {"attn": aa, "mlp": ma, "norm1": n1a, "norm2": n2a},
    )


def _prep(axes_tree, name="layers"):
    return jax.tree.map(
        lambda ax: (name,) + tuple(ax),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 5)
    emb_p, emb_a = L.embed_init(
        ks[0], cfg.padded_vocab, cfg.d_model, dtype=cfg.jdtype
    )
    n_groups, per_group, trailing = _plan(cfg)
    n_grouped = n_groups * per_group
    p: Params = {"embed": emb_p}
    a: Params = {"embed": emb_a}
    _, m_a1 = _mamba_init(ks[1], cfg)
    if n_grouped:
        gkeys = jax.random.split(ks[1], n_grouped).reshape(
            n_groups, per_group, 2
        )
        p["grouped"] = jax.vmap(
            jax.vmap(lambda k: _mamba_init(k, cfg)[0])
        )(gkeys)
        a["grouped"] = _prep(_prep(m_a1, "blocks"), "layers")
        sp, sa = _attn_block_init(ks[2], cfg)
        p["shared_attn"] = sp
        a["shared_attn"] = sa
    if trailing:
        tkeys = jax.random.split(ks[3], trailing)
        p["trailing"] = jax.vmap(lambda k: _mamba_init(k, cfg)[0])(tkeys)
        a["trailing"] = _prep(m_a1)
    fn_p, fn_a = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    p["final_norm"] = fn_p
    a["final_norm"] = fn_a
    return p, a


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _mamba_block(lp, x, cfg: ModelConfig, cache=None):
    h = L.rmsnorm(lp["norm"], x, eps=cfg.norm_eps)
    y, new_cache = ssd.ssd_block_apply(
        lp["mixer"],
        h,
        d_inner=cfg.d_inner,
        headdim=cfg.ssm_headdim,
        d_state=cfg.ssm_state,
        chunk=cfg.ssm_chunk,
        cache=cache,
        norm_eps=cfg.norm_eps,
    )
    return x + y, new_cache


def _attn_block(lp, x, cfg: ModelConfig, positions, mode):
    h = L.rmsnorm(lp["norm1"], x, eps=cfg.norm_eps)
    q, k, v = L.attn_qkv(lp["attn"], h)
    q = L.rope(q, positions, base=cfg.rope_base)
    k = L.rope(k, positions, base=cfg.rope_base)
    if mode == "chunked":
        ctx = L.attention_chunked(q, k, v, causal=True, block=cfg.attn_block)
    else:
        ctx = L.attention_dense(q, k, v, causal=True)
    x = x + L.attn_out(lp["attn"], ctx)
    h = L.rmsnorm(lp["norm2"], x, eps=cfg.norm_eps)
    return x + L.mlp(lp["mlp"], h, act=cfg.act)


def forward(params, cfg: ModelConfig, batch, *, mode: str = "dense"):
    x = L.embed(params["embed"], batch["tokens"], cfg.jdtype)
    positions = jnp.arange(x.shape[1])
    n_groups, per_group, trailing = _plan(cfg)

    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else None
    )
    mb = functools.partial(_mamba_block, cfg=cfg)
    mbr = (
        jax.checkpoint(lambda lp, x: mb(lp, x)[0], policy=policy)
        if cfg.remat
        else (lambda lp, x: mb(lp, x)[0])
    )

    if n_groups:
        shared = params["shared_attn"]

        def group_body(x, gp):
            x, _ = jax.lax.scan(lambda c, lp: (mbr(lp, c), None), x, gp)
            ab = functools.partial(
                _attn_block, cfg=cfg, positions=positions, mode=mode
            )
            fn = jax.checkpoint(ab, policy=policy) if cfg.remat else ab
            return fn(shared, x), None

        x, _ = jax.lax.scan(group_body, x, params["grouped"])
    if trailing:
        x, _ = jax.lax.scan(
            lambda c, lp: (mbr(lp, c), None), x, params["trailing"]
        )
    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    return L.unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch)
    logits = mask_pad_logits(logits.astype(jnp.float32), cfg)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll, "aux": aux}


def prefill(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch, mode="chunked")
    return logits


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    n_groups, per_group, trailing = _plan(cfg)
    H = cfg.d_inner // cfg.ssm_headdim
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state

    def ssm_cache(n, *lead):
        return {
            "conv": jnp.zeros(
                lead + (batch, ssd.CONV_K - 1, conv_dim), cfg.jdtype
            ),
            "ssm": jnp.zeros(
                lead + (batch, H, cfg.ssm_headdim, cfg.ssm_state), cfg.jdtype
            ),
        }

    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if n_groups:
        cache["grouped"] = ssm_cache(None, n_groups, per_group)
        kv = jnp.zeros(
            (n_groups, batch, max_len, cfg.eff_kv_heads, cfg.hd), cfg.jdtype
        )
        cache["attn_k"] = kv
        cache["attn_v"] = kv
    if trailing:
        cache["trailing"] = ssm_cache(None, trailing)
    return cache


def cache_axes(cfg: ModelConfig) -> Dict:
    n_groups, per_group, trailing = _plan(cfg)
    sax = {
        "conv": ("batch", "conv", "d_inner"),
        "ssm": ("batch", "ssm_heads", None, "ssm_state"),
    }
    ax: Dict[str, Any] = {"pos": ()}
    if n_groups:
        ax["grouped"] = {
            "conv": ("layers", "blocks") + sax["conv"],
            "ssm": ("layers", "blocks") + sax["ssm"],
        }
        ax["attn_k"] = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        ax["attn_v"] = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    if trailing:
        ax["trailing"] = {
            "conv": ("layers",) + sax["conv"],
            "ssm": ("layers",) + sax["ssm"],
        }
    return ax


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens: jax.Array):
    x = L.embed(params["embed"], tokens, cfg.jdtype)
    pos = cache["pos"]
    positions = pos[None, None] + jnp.zeros((1, 1), jnp.int32)
    n_groups, per_group, trailing = _plan(cfg)
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    def mamba_step(x, lp, c):
        return _mamba_block(lp, x, cfg, cache=c)

    if n_groups:
        shared = params["shared_attn"]

        def group_body(x, inp):
            gp, gc, kc, vc = inp

            def inner(x, blk):
                lp, c = blk
                x, nc = mamba_step(x, lp, c)
                return x, nc

            x, ncs = jax.lax.scan(inner, x, (gp, gc))
            # shared attention with its per-application KV cache
            h = L.rmsnorm(shared["norm1"], x, eps=cfg.norm_eps)
            q, k, v = L.attn_qkv(shared["attn"], h)
            q = L.rope(q, positions, base=cfg.rope_base)
            k = L.rope(k, positions, base=cfg.rope_base)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
            ctx = L.attention_decode(q, kc, vc, pos + 1)
            x = x + L.attn_out(shared["attn"], ctx)
            h = L.rmsnorm(shared["norm2"], x, eps=cfg.norm_eps)
            x = x + L.mlp(shared["mlp"], h, act=cfg.act)
            return x, (ncs, kc, vc)

        x, (g_ncs, k_new, v_new) = jax.lax.scan(
            group_body,
            x,
            (
                params["grouped"],
                cache["grouped"],
                cache["attn_k"],
                cache["attn_v"],
            ),
        )
        new_cache["grouped"] = g_ncs
        new_cache["attn_k"] = k_new
        new_cache["attn_v"] = v_new
    if trailing:
        def tbody(x, blk):
            lp, c = blk
            x, nc = mamba_step(x, lp, c)
            return x, nc

        x, t_ncs = jax.lax.scan(tbody, x, (params["trailing"], cache["trailing"]))
        new_cache["trailing"] = t_ncs
    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = mask_pad_logits(L.unembed(params["embed"], x), cfg)
    return logits, new_cache
