"""Model families: dense/MoE/VLM decoder LMs, enc-dec, SSM, hybrid."""
from repro.models.config import ModelConfig
from repro.models.registry import get_family, input_specs, make_batch

__all__ = ["ModelConfig", "get_family", "input_specs", "make_batch"]
