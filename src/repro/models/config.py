"""Unified model configuration covering the 6 assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | encdec | vlm | ssm | hybrid | moe
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_fraction: float = 1.0  # 0.5 == ChatGLM "RoPE 2d" (half-rotary)
    rope_base: float = 10000.0
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_shared_d_ff: Optional[int] = None
    moe_parallelism: str = "tensor"  # tensor | expert
    capacity_factor: float = 1.25
    moe_group_size: int = 4096
    aux_loss_coef: float = 0.01
    moe_dispatch: str = "einsum"  # "gather" = §Perf row-dispatch (ours)

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # --- hybrid (zamba2): shared attention block every N mamba blocks ---
    attn_every: int = 6

    # --- enc-dec (whisper): encoder consumes frontend-stub embeddings ---
    n_enc_layers: int = 0
    enc_seq: int = 1500

    # --- VLM: patch-embedding prefix from the vision-frontend stub ---
    n_patches: int = 0
    vision_dim: int = 0

    # --- numerics / execution ---
    dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"  # "dots" saves matmul/psum outputs (§Perf)
    pad_heads: Optional[int] = None  # pad q-heads for TP divisibility (§Perf)
    vocab_pad_multiple: int = 256
    attn_block: int = 1024  # chunked-attention KV block (prefill)
    source: str = ""  # citation for the assigned config

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return _DTYPES[self.dtype]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def eff_heads(self) -> int:
        """Query heads after §Perf padding (pad_heads)."""
        return max(self.n_heads, self.pad_heads or 0)

    @property
    def eff_kv_heads(self) -> int:
        if self.pad_heads and self.pad_heads > self.n_heads:
            ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
            return max(1, self.pad_heads // ratio)
        return self.n_kv_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke_variant(self) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests
        (<=2 layers, d_model <= 512, <= 4 experts)."""
        kw = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            dtype="float32",
            remat=False,
            moe_group_size=256,
        )
        kw["n_heads"] = min(self.n_heads, 4)
        kw["n_kv_heads"] = min(self.n_kv_heads, max(1, kw["n_heads"] // 2))
        if self.n_heads and kw["n_heads"] % kw["n_kv_heads"]:
            kw["n_kv_heads"] = 1
        kw["head_dim"] = 32
        if self.is_moe:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["moe_top_k"] = min(self.moe_top_k, 2)
            kw["moe_shared_d_ff"] = (
                min(self.moe_shared_d_ff, 256) if self.moe_shared_d_ff else None
            )
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 16)
            kw["ssm_headdim"] = 16
            kw["ssm_chunk"] = 32
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.n_patches:
            kw["n_patches"] = 8
            kw["vision_dim"] = 64
        if self.family == "hybrid":
            kw["n_layers"] = 5  # 2 groups: (2 mamba + attn) x2 rotation
            kw["attn_every"] = 3
        if self.sliding_window:
            kw["sliding_window"] = 16
        return self.replace(**kw)
