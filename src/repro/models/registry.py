"""Family dispatch + input specs (ShapeDtypeStruct stand-ins for dry-runs).

Every family module exposes:
    init(key, cfg) -> (params, logical_axes)
    loss_fn(params, cfg, batch) -> (loss, metrics)
    prefill(params, cfg, batch) -> logits
    init_cache(cfg, batch, max_len) -> cache
    cache_axes(cfg) -> logical axes for the cache
    decode_step(params, cfg, cache, tokens) -> (logits, cache)
"""
from __future__ import annotations

from types import ModuleType
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, lm, ssm
from repro.models.config import ModelConfig

_FAMILIES: Dict[str, ModuleType] = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "encdec": encdec,
    "ssm": ssm,
    "hybrid": ssm,
}


def get_family(cfg: ModelConfig) -> ModuleType:
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(
            f"unknown family {cfg.family!r}; available: {sorted(_FAMILIES)}"
        ) from None


def input_specs(
    cfg: ModelConfig, batch: int, seq: int, *, kind: str = "train"
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    kind: "train" (tokens+labels+frontend stubs) or "decode" (one token).
    """
    i32 = jnp.int32
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), cfg.jdtype
        )
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.vision_dim), cfg.jdtype
        )
    return specs


def make_batch(cfg: ModelConfig, batch: int, seq: int, key=None) -> Dict:
    """Concrete synthetic batch matching ``input_specs`` (smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.enc_seq, cfg.d_model), cfg.jdtype
        )
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            ks[3], (batch, cfg.n_patches, cfg.vision_dim), cfg.jdtype
        )
    return out
