"""Encoder-decoder transformer (whisper-tiny backbone).

Per the assignment carve-out, the audio frontend (mel + conv) is a stub:
``input_specs`` provides precomputed frame embeddings ``[B, enc_seq,
d_model]``. The encoder is bidirectional; the decoder is causal with
cross-attention. RoPE replaces whisper's learned positions (TPU-idiomatic;
noted in DESIGN.md) — the backbone compute/communication profile is
identical.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import mask_pad_logits
from repro.nn import layers as L

Params = Dict[str, Any]


def _norm(cfg):
    if cfg.norm == "rmsnorm":
        return L.rmsnorm_init, functools.partial(L.rmsnorm, eps=cfg.norm_eps)
    return L.layernorm_init, functools.partial(L.layernorm, eps=cfg.norm_eps)


def _enc_layer_init(key, cfg) -> Tuple[Params, Params]:
    ninit, _ = _norm(cfg)
    k1, k2 = jax.random.split(key)
    ap, aa = L.attn_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
        dtype=cfg.jdtype, pad_to=cfg.pad_heads,
    )
    mp, ma = L.mlp_init(k2, cfg.d_model, cfg.d_ff, act=cfg.act, dtype=cfg.jdtype)
    n1p, n1a = ninit(cfg.d_model, cfg.jdtype)
    n2p, n2a = ninit(cfg.d_model, cfg.jdtype)
    return (
        {"attn": ap, "mlp": mp, "norm1": n1p, "norm2": n2p},
        {"attn": aa, "mlp": ma, "norm1": n1a, "norm2": n2a},
    )


def _dec_layer_init(key, cfg) -> Tuple[Params, Params]:
    ninit, _ = _norm(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    sp, sa = L.attn_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
        dtype=cfg.jdtype, pad_to=cfg.pad_heads,
    )
    cp, ca = L.attn_init(
        k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
        dtype=cfg.jdtype, pad_to=cfg.pad_heads,
    )
    mp, ma = L.mlp_init(k3, cfg.d_model, cfg.d_ff, act=cfg.act, dtype=cfg.jdtype)
    norms_p, norms_a = {}, {}
    for i in (1, 2, 3):
        np_, na_ = ninit(cfg.d_model, cfg.jdtype)
        norms_p[f"norm{i}"] = np_
        norms_a[f"norm{i}"] = na_
    return (
        {"self": sp, "cross": cp, "mlp": mp, **norms_p},
        {"self": sa, "cross": ca, "mlp": ma, **norms_a},
    )


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    emb_p, emb_a = L.embed_init(
        ks[0], cfg.padded_vocab, cfg.d_model, dtype=cfg.jdtype
    )
    ekeys = jax.random.split(ks[1], cfg.n_enc_layers)
    enc_p = jax.vmap(lambda k: _enc_layer_init(k, cfg)[0])(ekeys)
    _, enc_a1 = _enc_layer_init(ks[1], cfg)
    dkeys = jax.random.split(ks[2], cfg.n_layers)
    dec_p = jax.vmap(lambda k: _dec_layer_init(k, cfg)[0])(dkeys)
    _, dec_a1 = _dec_layer_init(ks[2], cfg)
    prep = lambda t: jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        t,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )
    ninit, _ = _norm(cfg)
    fe_p, fe_a = ninit(cfg.d_model, cfg.jdtype)
    fd_p, fd_a = ninit(cfg.d_model, cfg.jdtype)
    p = {
        "embed": emb_p,
        "enc_layers": enc_p,
        "dec_layers": dec_p,
        "enc_norm": fe_p,
        "final_norm": fd_p,
    }
    a = {
        "embed": emb_a,
        "enc_layers": prep(enc_a1),
        "dec_layers": prep(dec_a1),
        "enc_norm": fe_a,
        "final_norm": fd_a,
    }
    return p, a


def _encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, enc_seq, d_model] from the frontend stub."""
    _, norm = _norm(cfg)
    x = frames.astype(cfg.jdtype)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = norm(lp["norm1"], x)
        q, k, v = L.attn_qkv(lp["attn"], h)
        q = L.rope(q, positions, base=cfg.rope_base)
        k = L.rope(k, positions, base=cfg.rope_base)
        ctx = L.attention_dense(q, k, v, causal=False)
        x = x + L.attn_out(lp["attn"], ctx)
        h = norm(lp["norm2"], x)
        return x + L.mlp(lp["mlp"], h, act=cfg.act), None

    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else None
    )
    fn = jax.checkpoint(body, policy=policy) if cfg.remat else body
    x, _ = jax.lax.scan(lambda c, lp: fn(c, lp), x, params["enc_layers"])
    return norm(params["enc_norm"], x)


def _dec_block(lp, x, cfg, positions, enc_out, mode):
    _, norm = _norm(cfg)
    h = norm(lp["norm1"], x)
    q, k, v = L.attn_qkv(lp["self"], h)
    q = L.rope(q, positions, base=cfg.rope_base)
    k = L.rope(k, positions, base=cfg.rope_base)
    if mode == "chunked":
        ctx = L.attention_chunked(q, k, v, causal=True, block=cfg.attn_block)
    else:
        ctx = L.attention_dense(q, k, v, causal=True)
    x = x + L.attn_out(lp["self"], ctx)
    h = norm(lp["norm2"], x)
    q, ck, cv = L.attn_qkv(lp["cross"], h, xkv=enc_out)
    ctx = L.attention_dense(q, ck, cv, causal=False)
    x = x + L.attn_out(lp["cross"], ctx)
    h = norm(lp["norm3"], x)
    return x + L.mlp(lp["mlp"], h, act=cfg.act)


def forward(params, cfg: ModelConfig, batch, *, mode: str = "dense"):
    enc_out = _encode(params, cfg, batch["frames"])
    x = L.embed(params["embed"], batch["tokens"], cfg.jdtype)
    positions = jnp.arange(x.shape[1])

    blk = lambda lp, x: _dec_block(lp, x, cfg, positions, enc_out, mode)
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else None
    )

    def body(x, lp):
        fn = jax.checkpoint(blk, policy=policy) if cfg.remat else blk
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    _, norm = _norm(cfg)
    x = norm(params["final_norm"], x)
    return L.unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch)
    logits = mask_pad_logits(logits.astype(jnp.float32), cfg)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll, "aux": aux}


def prefill(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch, mode="chunked")
    return logits


def build_cross_cache(params, cfg: ModelConfig, frames: jax.Array):
    """Prefill the cross-attention KV cache from the encoder output."""
    enc_out = _encode(params, cfg, frames)

    def per_layer(lp):
        wk = lp["cross"]["wk"].astype(enc_out.dtype)
        wv = lp["cross"]["wv"].astype(enc_out.dtype)
        k = jnp.einsum("bse,ehd->bshd", enc_out, wk)
        v = jnp.einsum("bse,ehd->bshd", enc_out, wv)
        return k, v

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return ck, cv


# --- decode: self-attn KV cache + precomputed cross-attn KV ---------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    kv = jnp.zeros(
        (cfg.n_layers, batch, max_len, cfg.eff_kv_heads, cfg.hd), cfg.jdtype
    )
    ckv = jnp.zeros(
        (cfg.n_layers, batch, cfg.enc_seq, cfg.eff_kv_heads, cfg.hd), cfg.jdtype
    )
    return {
        "k": kv,
        "v": kv,
        "ck": ckv,
        "cv": ckv,
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> Dict:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    cax = ("layers", "batch", "enc_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "ck": cax, "cv": cax, "pos": ()}


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens: jax.Array):
    x = L.embed(params["embed"], tokens, cfg.jdtype)
    pos = cache["pos"]
    positions = pos[None, None] + jnp.zeros((1, 1), jnp.int32)
    _, norm = _norm(cfg)

    def body(x, lp_caches):
        lp, kc, vc, ck, cv = lp_caches
        h = norm(lp["norm1"], x)
        q, k, v = L.attn_qkv(lp["self"], h)
        q = L.rope(q, positions, base=cfg.rope_base)
        k = L.rope(k, positions, base=cfg.rope_base)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        x = x + L.attn_out(lp["self"], L.attention_decode(q, kc, vc, pos + 1))
        h = norm(lp["norm2"], x)
        q = jnp.einsum("bse,ehd->bshd", h, lp["cross"]["wq"].astype(h.dtype))
        ctx = L.attention_decode(q, ck, cv, jnp.asarray(cfg.enc_seq))
        x = x + L.attn_out(lp["cross"], ctx)
        h = norm(lp["norm3"], x)
        return x + L.mlp(lp["mlp"], h, act=cfg.act), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"])
    )
    x = norm(params["final_norm"], x)
    logits = mask_pad_logits(L.unembed(params["embed"], x), cfg)
    return logits, {**cache, "k": k_new, "v": v_new, "pos": pos + 1}
