"""Collective aggregation strategies over codec payloads.

Every strategy realizes the paper's Eq. (8) server reduction — the weighted
sum of the workers' sparsified gradients — under one common interface, in two
forms:

* ``reference(codec, payloads, weights, length)`` — single-process: the
  worker axis is a real leading array axis ``[N, ...]`` (the simulator and
  the property tests drive this form).
* ``shard(codec, payload, length, axis_names, weight)`` — inside
  ``jax.shard_map``: ``payload`` is this worker's local encoded payload and
  the reduction runs over the named data-parallel mesh axes.

Strategies:

* ``dense_allreduce``   — psum of the sparse-but-dense vector. Ignores the
  codec (nothing is encoded on the wire); numerically exact; the
  uncompressed ``J``-words baseline every other pair is tested against.
* ``sparse_allgather``  — all_gather the encoded payload leaves over the dp
  axes, decode all ``N`` payloads locally, scatter-add. ``N ·
  wire_bits(codec)`` bits moved instead of dense words — the paper's
  compression with XLA-static shapes.
* ``hierarchical``      — for multi-axis dp meshes ``(*inter, intra)``
  (outermost/slowest first, e.g. ``("pod", "data")``): all_gather payloads
  over the *inter* axes (slow links move compressed payloads only), decode
  + scatter-add locally, then a dense psum over the innermost *intra* axis
  (fast links move dense partials). Degenerates to a psum of the decoded
  payload on a single-axis mesh.

Partial participation composes with every strategy through the optional
``participation`` argument rather than being baked into any of them
(:mod:`repro.comm.participation`):

* ``reference(..., participation=mask)`` — ``mask`` is the round's
  ``{0,1}`` participation vector ``[N]``; the weights are masked and
  renormalized to sum to one before the reduction.
* ``shard(..., participation=m)`` — ``m`` is *this worker's* scalar mask
  entry; its contribution is scaled by ``m`` (the caller supplies the
  already-renormalized participant weight, computable locally because
  schedules are deterministic common knowledge).

``participation=None`` (the default) is the historical all-workers path,
bit-for-bit.

Aggregation weighting is a second orthogonal axis (``WEIGHTINGS``):

* ``"worker"`` — the historical Eq. (8) reduction above: each worker's
  payload is scaled by its (renormalized) per-worker weight omega_n. With
  sparse payloads this under-weights coordinates that only a few workers
  selected — the aggregate is a union of per-worker top-k sets, and a
  coordinate sent by one worker out of N arrives scaled by omega_n ≈ 1/N.
* ``"coordinate"`` — the fed_dropout_avg renormalize-by-who-actually-sent
  reduction: per coordinate ``j`` the weighted sum is divided by the mass
  of the workers that sent ``j``, ``den[j] = Σ_{n : j∈mask_n} omega_n``,
  so the per-coordinate effective weights always sum to one over the
  senders. Exposed as ``reference_coord`` / ``shard_coord``, which return
  ``(agg, den)`` — callers thread ``den`` back into RegTop-k's posterior
  so Line-8's Delta conditions on the omega the server actually used.

Presence is defined on the decoded *values* (``!= 0``), not the index
slots: zero-padded payload slots and values a lossy codec (``coo_q8``)
quantized to exactly zero carry no sender mass.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.comm.codec import Codec, Payload
from repro.comm.participation import renormalize_weights


def _gather_payload(payload: Payload, axis_names: Sequence[str]) -> Payload:
    """all_gather every leaf over the given axes; the gathered axes are
    flattened into one leading worker-group axis: ``x.shape`` -> ``(N_g,) +
    x.shape`` (scalar leaves such as ``coo_q8``'s scale become ``[N_g]``)."""

    def gather_leaf(x):
        g = x
        for ax in axis_names:
            g = jax.lax.all_gather(g, ax)
        return g.reshape((-1,) + x.shape)

    return jax.tree.map(gather_leaf, payload)


def _reference_weights(weights, participation):
    """Renormalized per-worker weights for one reference-form round:
    ``participation`` (a ``{0,1}`` mask ``[N]``, or None for full) masks
    the base weights and renormalizes them to sum to one."""
    if participation is None:
        return weights
    w = jnp.asarray(weights)
    mask = jnp.asarray(participation)
    if jnp.ndim(w) == 0:
        w = jnp.full(mask.shape, w)
    return renormalize_weights(w, mask)


def _shard_weight(weight, participation):
    """This worker's effective weight inside ``shard_map``: its (already
    renormalized) participant weight scaled by its own mask entry."""
    if participation is None:
        return weight
    return weight * participation


WEIGHTINGS = ("worker", "coordinate")


def check_weighting(name: str) -> str:
    """Validate a ``weighting=`` axis value.

    >>> check_weighting("coordinate")
    'coordinate'
    >>> check_weighting("per-worker")
    Traceback (most recent call last):
        ...
    ValueError: unknown weighting 'per-worker'; available: \
['worker', 'coordinate']
    """
    if name not in WEIGHTINGS:
        raise ValueError(
            f"unknown weighting {name!r}; available: {list(WEIGHTINGS)}"
        )
    return name


def _coord_num_den(codec, payloads, weights, length):
    """Decode a ``[N, ...]`` payload stack into the coordinate-weighting
    sums: ``num[j] = Σ_n w_n·ghat_n[j]`` and the per-coordinate sender mass
    ``den[j] = Σ_n w_n·1[ghat_n[j] != 0]``, both ``[L]``.

    One flat scatter-add in worker-stack order for each sum, so the
    reference form and the gathered shard form (whose stacking order is the
    mesh-axis order — the same worker order) add in the same sequence and
    stay bit-for-bit."""
    vals, idx = jax.vmap(lambda p: codec.decode(p, length))(payloads)
    w = jnp.asarray(weights)
    if jnp.ndim(w) == 0:
        w = jnp.full((vals.shape[0],), w)
    presence = (vals != 0).astype(vals.dtype)
    flat_idx = idx.reshape(-1)
    num = (
        jnp.zeros((length,), vals.dtype)
        .at[flat_idx]
        .add((w[:, None] * vals).reshape(-1))
    )
    den = (
        jnp.zeros((length,), vals.dtype)
        .at[flat_idx]
        .add((w[:, None] * presence).reshape(-1))
    )
    return num, den


def _coord_divide(num: jax.Array, den: jax.Array) -> jax.Array:
    """``num / den`` with a dtype-derived floor: where no worker sent the
    coordinate (``den == 0``) the numerator is exactly zero too, so the
    floored divide yields 0 rather than NaN."""
    return num / jnp.maximum(den, jnp.finfo(den.dtype).tiny)


class Collective:
    name: str = "base"

    def reference(
        self,
        codec: Codec,
        payloads: Payload,
        weights: jax.Array,
        length: int,
        participation: Optional[jax.Array] = None,
    ) -> jax.Array:
        raise NotImplementedError

    def shard(
        self,
        codec: Codec,
        payload: Payload,
        length: int,
        axis_names: Sequence[str],
        weight: jax.Array | float,
        participation: Optional[jax.Array] = None,
    ) -> jax.Array:
        raise NotImplementedError

    def reference_coord(
        self,
        codec: Codec,
        payloads: Payload,
        weights: jax.Array,
        length: int,
        participation: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, jax.Array]:
        """``weighting="coordinate"`` reference form: returns ``(agg, den)``
        where ``den[j]`` is the sender mass the server divided by at ``j``
        (the coordinate-wise omega callers thread back into RegTop-k)."""
        raise NotImplementedError

    def shard_coord(
        self,
        codec: Codec,
        payload: Payload,
        length: int,
        axis_names: Sequence[str],
        weight: jax.Array | float,
        participation: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, jax.Array]:
        """``weighting="coordinate"`` shard_map form: ``(agg, den)``."""
        raise NotImplementedError


def _decode_scatter_stack(
    codec: Codec, payloads: Payload, weights, length: int
) -> jax.Array:
    """Decode a ``[N, ...]`` payload stack and weighted-scatter-add to [L]."""
    vals, idx = jax.vmap(lambda p: codec.decode(p, length))(payloads)
    if jnp.ndim(weights) == 0:
        wvals = vals * weights
    else:
        wvals = weights[:, None] * vals
    return (
        jnp.zeros((length,), vals.dtype)
        .at[idx.reshape(-1)]
        .add(wvals.reshape(-1))
    )


class SparseAllgather(Collective):
    name = "sparse_allgather"

    def reference(self, codec, payloads, weights, length, participation=None):
        w = _reference_weights(weights, participation)
        return _decode_scatter_stack(codec, payloads, w, length)

    def shard(
        self, codec, payload, length, axis_names, weight, participation=None
    ):
        if participation is None:
            gathered = _gather_payload(payload, axis_names)
            return _decode_scatter_stack(codec, gathered, weight, length)
        # partial round: gather each worker's own effective weight
        # (weight * its mask entry) alongside its payload, so the weights
        # arrive in exactly the gather's stacking order — a dropped
        # worker's payload rides the wire (SPMD) but lands with weight 0.
        # No payload transform, so this is exact for every codec.
        w_local = (
            jnp.asarray(weight, jnp.float32) * participation
        ).reshape((1,))
        gathered, w = _gather_payload((payload, w_local), axis_names)
        return _decode_scatter_stack(codec, gathered, w.reshape(-1), length)

    def reference_coord(
        self, codec, payloads, weights, length, participation=None
    ):
        w = _reference_weights(weights, participation)
        num, den = _coord_num_den(codec, payloads, w, length)
        return _coord_divide(num, den), den

    def shard_coord(
        self, codec, payload, length, axis_names, weight, participation=None
    ):
        # the per-worker weight always rides the gather alongside the
        # payload (even on full rounds): coordinate mode needs every
        # worker's weight locally to build den in gather-stack order.
        part = 1.0 if participation is None else participation
        w_local = (jnp.asarray(weight, jnp.float32) * part).reshape((1,))
        gathered, w = _gather_payload((payload, w_local), axis_names)
        num, den = _coord_num_den(codec, gathered, w.reshape(-1), length)
        return _coord_divide(num, den), den


class Hierarchical(Collective):
    """inter-axis allgather of payloads, intra-axis psum of the scattered
    partials.

    Mesh axes are ordered outermost (slow link) first — e.g. the repo's
    multi-pod dp ordering ``("pod", "data")`` — so the *last* axis is the
    intra (fast) one: compressed payloads traverse the slow outer axes via
    allgather, and only the fast innermost axis moves the dense partial.
    """

    name = "hierarchical"

    def reference(self, codec, payloads, weights, length, participation=None):
        # single-process: the grouping is notional — numerics are identical
        # to sparse_allgather (sum over all workers either way).
        w = _reference_weights(weights, participation)
        return _decode_scatter_stack(codec, payloads, w, length)

    def shard(
        self, codec, payload, length, axis_names, weight, participation=None
    ):
        inter, intra = tuple(axis_names[:-1]), axis_names[-1]
        if inter:
            partial = SparseAllgather().shard(
                codec, payload, length, inter, weight, participation
            )
        else:
            vals, idx = codec.decode(payload, length)
            w = _shard_weight(weight, participation)
            partial = (
                jnp.zeros((length,), vals.dtype).at[idx].add(vals * w)
            )
        return jax.lax.psum(partial, intra)

    def reference_coord(
        self, codec, payloads, weights, length, participation=None
    ):
        # single-process: identical to sparse_allgather (sum over all
        # workers either way) — the inter/intra grouping is notional.
        w = _reference_weights(weights, participation)
        num, den = _coord_num_den(codec, payloads, w, length)
        return _coord_divide(num, den), den

    def shard_coord(
        self, codec, payload, length, axis_names, weight, participation=None
    ):
        inter, intra = tuple(axis_names[:-1]), axis_names[-1]
        part = 1.0 if participation is None else participation
        w_local = (jnp.asarray(weight, jnp.float32) * part).reshape((1,))
        if inter:
            gathered, w = _gather_payload((payload, w_local), inter)
            num, den = _coord_num_den(codec, gathered, w.reshape(-1), length)
        else:
            vals, idx = codec.decode(payload, length)
            presence = (vals != 0).astype(vals.dtype)
            num = (
                jnp.zeros((length,), vals.dtype)
                .at[idx]
                .add(w_local[0] * vals)
            )
            den = (
                jnp.zeros((length,), vals.dtype)
                .at[idx]
                .add(w_local[0] * presence)
            )
        num = jax.lax.psum(num, intra)
        den = jax.lax.psum(den, intra)
        return _coord_divide(num, den), den


class DenseAllreduce(Collective):
    """Uncompressed baseline: the codec is bypassed (dense vector on wire).

    ``reference``/``shard`` still accept payloads for interface uniformity:
    they decode (a no-op for the fp32 codec) and psum the dense vector, which
    is bit-identical to the historical ``aggregate.allreduce_dense`` path.
    """

    name = "dense_allreduce"

    def reference(self, codec, payloads, weights, length, participation=None):
        dense = jax.vmap(lambda p: codec.decoded_dense(p, length))(payloads)
        w = (
            jnp.full((dense.shape[0],), weights)
            if jnp.ndim(weights) == 0
            else weights
        )
        w = _reference_weights(w, participation)
        return jnp.einsum("n,nl->l", w, dense)

    def shard(
        self, codec, payload, length, axis_names, weight, participation=None
    ):
        dense = codec.decoded_dense(payload, length)
        w = _shard_weight(weight, participation)
        return jax.lax.psum(dense * w, tuple(axis_names))

    def reference_coord(
        self, codec, payloads, weights, length, participation=None
    ):
        # dense on the wire, but the *sparsified* gradient is zero off the
        # selected coordinates — presence still identifies the sender set.
        dense = jax.vmap(lambda p: codec.decoded_dense(p, length))(payloads)
        w = (
            jnp.full((dense.shape[0],), weights)
            if jnp.ndim(weights) == 0
            else weights
        )
        w = _reference_weights(w, participation)
        presence = (dense != 0).astype(dense.dtype)
        num = jnp.einsum("n,nl->l", w, dense)
        den = jnp.einsum("n,nl->l", w, presence)
        return _coord_divide(num, den), den

    def shard_coord(
        self, codec, payload, length, axis_names, weight, participation=None
    ):
        dense = codec.decoded_dense(payload, length)
        w = _shard_weight(weight, participation)
        presence = (dense != 0).astype(dense.dtype)
        num = jax.lax.psum(dense * w, tuple(axis_names))
        den = jax.lax.psum(presence * w, tuple(axis_names))
        return _coord_divide(num, den), den


COLLECTIVES = {
    c.name: c
    for c in (DenseAllreduce(), SparseAllgather(), Hierarchical())
}


# ---------------------------------------------------------------------------
# single-process reference reductions (worker axis is a real array axis) and
# legacy in-shard_map helpers — formerly ``repro.core.aggregate``.
# ---------------------------------------------------------------------------
def dense_mean(ghat_stack: jax.Array, weights: jax.Array) -> jax.Array:
    """``ghat_stack``: [N, L]; ``weights``: [N] (omega_n, sum to 1).

    >>> import jax.numpy as jnp
    >>> g = jnp.array([[2.0, 0.0], [0.0, 4.0]])
    >>> dense_mean(g, jnp.array([0.5, 0.5])).tolist()
    [1.0, 2.0]
    """
    return jnp.einsum("n,nl->l", weights, ghat_stack)


def scatter_add_payloads(
    vals: jax.Array, idx: jax.Array, weights: jax.Array, length: int
) -> jax.Array:
    """``vals``/``idx``: [N, k]; returns the weighted dense sum, [L].

    >>> import jax.numpy as jnp
    >>> vals = jnp.array([[2.0], [4.0]])
    >>> idx = jnp.array([[1], [1]])
    >>> scatter_add_payloads(vals, idx, jnp.array([0.5, 0.5]), 3).tolist()
    [0.0, 3.0, 0.0]
    """
    flat_vals = (weights[:, None] * vals).reshape(-1)
    flat_idx = idx.reshape(-1)
    return jnp.zeros((length,), vals.dtype).at[flat_idx].add(flat_vals)


def allreduce_dense(
    ghat: jax.Array, axis_names: Sequence[str], weight: jax.Array | float
) -> jax.Array:
    """Weighted dense allreduce over the dp axes (uncompressed pattern).

    Callable only inside ``shard_map`` (named-axis psum):

    >>> agg = allreduce_dense(ghat, ("data",), 1.0 / 8)  # doctest: +SKIP
    """
    return jax.lax.psum(ghat * weight, tuple(axis_names))


def allgather_scatter(
    vals: jax.Array,
    idx: jax.Array,
    length: int,
    axis_names: Sequence[str],
    weight: jax.Array | float,
) -> jax.Array:
    """Compressed aggregation with the fp32 COO wire format — equivalent to
    ``SparseAllgather().shard(get_codec("coo_fp32"), ...)``.

    Callable only inside ``shard_map`` (named-axis all_gather):

    >>> agg = allgather_scatter(vals, idx, L, ("data",), w)  # doctest: +SKIP
    """
    from repro.comm.codec import get_codec

    payload = get_codec("coo_fp32").encode(vals, idx, length)
    return SparseAllgather().shard(
        get_codec("coo_fp32"), payload, length, axis_names, weight
    )


def get_collective(name: str) -> Collective:
    """Look up a registered collective strategy by name.

    >>> get_collective("hierarchical").name
    'hierarchical'
    >>> get_collective("bogus")
    Traceback (most recent call last):
        ...
    ValueError: unknown collective 'bogus'; available: ['dense_allreduce', \
'hierarchical', 'sparse_allgather']
    """
    try:
        return COLLECTIVES[name]
    except KeyError:
        raise ValueError(
            f"unknown collective {name!r}; available: {sorted(COLLECTIVES)}"
        ) from None
