"""Adaptive compression controller: error-budget-driven per-round k.

The paper fixes the sparsification factor S = k/J for the whole run; the
adaptive-sparsification literature ("Adaptive Top-K in SGD", arXiv:
2210.13532; "Rethinking gradient sparsification as total error
minimization", arXiv:2108.00951) shows the right k is a *feedback*
quantity: the accumulated sparsification error ``||eps||`` relative to the
aggregated gradient ``||g_agg||`` measures how much signal the wire is
withholding, and k should grow when that ratio overshoots a target budget
and shrink when it undershoots.

:class:`AdaptiveKController` implements that loop per leaf:

* the measured ratio ``||eps|| / ||g_agg||`` is smoothed with the same
  exponential discounting ``SparsifierConfig.momentum`` uses
  (``r <- m * r + (1 - m) * raw``);
* the *pressure* ``r / budget`` drives a multiplicative k update, clamped
  to one ``gain`` factor per round and to static bounds ``[k_min, k_max]``;
* a relative ``hysteresis`` dead band around pressure 1 keeps k still when
  the ratio merely jitters about the budget, so the payload capacity is
  not re-planned on noise.

Everything the traced step touches (:meth:`AdaptiveKController.observe`,
:meth:`AdaptiveKController.plan_k`, :class:`ControllerState`) is pure
``jnp`` on scalar operands — k is a *dynamic* operand of the compiled
round, never a trace constant, so a k change does not retrace (the payload
rides at the static capacity ``k_max``; see
``repro.core.compact.compact_select``'s ``k_dyn``).

Wire pricing stays codec-agnostic through :func:`round_wire_bits`: the
controller only ever reasons about k, and any bytes accounting delegates
to ``Codec.wire_bits`` — a future entropy-coded index codec changes the
bits-per-coordinate without touching the control law.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

_TINY = 1e-30  # guards the error ratio against a zero aggregated gradient


class ControllerState(NamedTuple):
    """Per-leaf controller state (all scalars — cheap to carry/replicate).

    err_ratio — discounted ``||eps|| / ||g_agg||`` estimate (f32).
    k         — the k the *next* round will send (int32).
    t         — rounds observed (int32); t == 0 skips the discounting.
    """

    err_ratio: jax.Array
    k: jax.Array
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class AdaptiveKController:
    """Maps posterior error statistics to a per-round k.

    budget     — target error ratio ``||eps|| / ||g_agg||`` the loop
                 regulates to (the total-error budget, normalized).
    k_min/k_max— per-leaf bounds; values in (0, 1) are fractions of the
                 leaf length (resolved like ``sparsity_to_k``), values
                 >= 1 are absolute coordinate counts. ``k_max`` is also
                 the static payload *capacity* the traced step allocates.
    momentum   — exponential discount on the measured ratio
                 (``SparsifierConfig.momentum``-style; 0 disables).
    hysteresis — relative dead band around pressure 1: within
                 ``[1 - h, 1 + h]`` the previous k is kept.
    gain       — max multiplicative k step per round (> 1).
    """

    budget: float
    k_min: float = 1.0
    k_max: float = 0.25
    momentum: float = 0.9
    hysteresis: float = 0.25
    gain: float = 2.0

    def __post_init__(self):
        if not self.budget > 0:
            raise ValueError(f"budget must be > 0, got {self.budget}")
        if not 0 <= self.momentum < 1:
            raise ValueError(
                f"momentum must be in [0, 1), got {self.momentum}"
            )
        if self.hysteresis < 0:
            raise ValueError(
                f"hysteresis must be >= 0, got {self.hysteresis}"
            )
        if not self.gain > 1:
            raise ValueError(f"gain must be > 1, got {self.gain}")
        if self.k_min <= 0 or self.k_max <= 0:
            raise ValueError(
                f"k bounds must be > 0, got [{self.k_min}, {self.k_max}]"
            )
        same_kind = (self.k_min < 1) == (self.k_max < 1)
        if same_kind and self.k_min > self.k_max:
            raise ValueError(
                f"k_min {self.k_min} > k_max {self.k_max}"
            )

    # -- static (trace-time) resolution -----------------------------------
    def bounds(self, length: int) -> Tuple[int, int]:
        """Resolve ``[k_min, k_max]`` to absolute ints for one leaf.

        Fractions go through the same epsilon-tolerant ceil as the static
        sparsity (``selectors.sparsity_to_k``); everything clips to
        ``[1, length]`` and the pair must stay ordered after resolution.

        >>> AdaptiveKController(budget=0.5).bounds(1000)
        (1, 250)
        >>> AdaptiveKController(budget=0.5, k_min=0.01, k_max=64).bounds(1000)
        (10, 64)
        """
        from repro.core.selectors import sparsity_to_k

        def resolve(b: float) -> int:
            if b < 1.0:
                return sparsity_to_k(length, b)
            return max(1, min(int(length), int(b)))

        lo, hi = resolve(self.k_min), resolve(self.k_max)
        if lo > hi:
            raise ValueError(
                f"k bounds [{self.k_min}, {self.k_max}] resolve to "
                f"[{lo}, {hi}] on a length-{length} leaf"
            )
        return lo, hi

    def init(self, k0: int, k_min: int, k_max: int) -> ControllerState:
        """Initial state: start at the static k, clipped into bounds.

        >>> st = AdaptiveKController(budget=0.5).init(5, 1, 250)
        >>> int(st.k), int(st.t)
        (5, 0)
        """
        k = max(int(k_min), min(int(k_max), int(k0)))
        return ControllerState(
            err_ratio=jnp.zeros((), jnp.float32),
            k=jnp.asarray(k, jnp.int32),
            t=jnp.zeros((), jnp.int32),
        )

    # -- traced control law -----------------------------------------------
    def plan_k(
        self,
        err_ratio: jax.Array,
        k: jax.Array,
        k_min: int,
        k_max: int,
    ) -> jax.Array:
        """One multiplicative k update from the smoothed error ratio.

        ``pressure = err_ratio / budget``; above the dead band k grows by
        ``ceil(k * min(pressure, gain))``, below it shrinks by
        ``floor(k * max(pressure, 1/gain))``, inside it k is kept — so the
        planned k is monotone non-decreasing in pressure (equivalently:
        non-increasing in the error-budget slack ``budget - err_ratio``),
        and always lands in ``[k_min, k_max]``.

        >>> c = AdaptiveKController(budget=0.1, hysteresis=0.25, gain=2.0)
        >>> int(c.plan_k(jnp.asarray(0.4), jnp.asarray(16), 1, 256))
        32
        >>> int(c.plan_k(jnp.asarray(0.025), jnp.asarray(16), 1, 256))
        8
        >>> int(c.plan_k(jnp.asarray(0.11), jnp.asarray(16), 1, 256))
        16
        """
        pressure = err_ratio / self.budget
        scale = jnp.clip(pressure, 1.0 / self.gain, self.gain)
        kf = k.astype(jnp.float32)
        grown = jnp.ceil(kf * scale)
        shrunk = jnp.floor(kf * scale)
        kept = jnp.where(
            pressure > 1.0 + self.hysteresis,
            grown,
            jnp.where(pressure < 1.0 - self.hysteresis, shrunk, kf),
        )
        return jnp.clip(kept, k_min, k_max).astype(jnp.int32)

    def observe(
        self,
        state: ControllerState,
        eps_norm: jax.Array,
        g_norm: jax.Array,
        *,
        k_min: int,
        k_max: int,
    ) -> ControllerState:
        """Fold one round's measured norms into the state; plan next k.

        The raw ratio ``eps_norm / max(g_norm, tiny)`` is discounted with
        ``momentum`` (the first observation seeds the estimate directly),
        then :meth:`plan_k` turns it into the next round's k. Pure ``jnp``
        — safe inside jit/scan with k as a dynamic operand.

        >>> c = AdaptiveKController(budget=0.1, momentum=0.5)
        >>> st = c.init(16, 1, 256)
        >>> st = c.observe(st, jnp.asarray(4.0), jnp.asarray(10.0),
        ...                k_min=1, k_max=256)
        >>> round(float(st.err_ratio), 3), int(st.k)
        (0.4, 32)
        """
        raw = eps_norm.astype(jnp.float32) / jnp.maximum(
            g_norm.astype(jnp.float32), _TINY
        )
        smoothed = jnp.where(
            state.t == 0,
            raw,
            self.momentum * state.err_ratio + (1.0 - self.momentum) * raw,
        )
        return ControllerState(
            err_ratio=smoothed,
            k=self.plan_k(smoothed, state.k, k_min, k_max),
            t=state.t + 1,
        )


def round_wire_bits(codec: str, length: int, k: int) -> int:
    """Bits one worker's payload puts on the wire at dynamic k.

    The codec-agnostic pricing hook for budget sweeps and metrics: the
    controller reasons purely about k, and every bytes question delegates
    to ``Codec.wire_bits`` — swapping in a cheaper index encoding changes
    the bits per coordinate here without touching the control law.

    >>> round_wire_bits("coo_fp32", 1000, 10)
    640
    """
    from repro.comm.codec import get_codec

    return int(get_codec(codec).wire_bits(int(length), int(k)))


def parse_adaptive_k(spec: str) -> AdaptiveKController:
    """Parse the train CLI's ``--adaptive-k budget[,k_min,k_max]`` spec.

    Bounds follow :class:`AdaptiveKController`'s convention: values in
    (0, 1) are fractions of each leaf's length, values >= 1 absolute
    coordinate counts.

    >>> parse_adaptive_k("0.1").budget
    0.1
    >>> c = parse_adaptive_k("0.1,4,64")
    >>> (c.k_min, c.k_max)
    (4.0, 64.0)
    """
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if len(parts) not in (1, 3):
        raise ValueError(
            f"expected 'budget' or 'budget,k_min,k_max', got {spec!r}"
        )
    try:
        nums = [float(p) for p in parts]
    except ValueError:
        raise ValueError(
            f"non-numeric --adaptive-k field in {spec!r}"
        ) from None
    if len(nums) == 1:
        return AdaptiveKController(budget=nums[0])
    return AdaptiveKController(
        budget=nums[0], k_min=nums[1], k_max=nums[2]
    )
