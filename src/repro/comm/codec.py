"""Wire codecs: interchangeable payload encodings with exact bit accounting.

A codec turns the sparsifier's fixed-k payload ``(vals [k], idx [k])`` over a
length-``L`` flat gradient shard into a pytree of statically-shaped arrays
(the *wire payload*) and back. Static shapes are non-negotiable: the payload
is what ``all_gather`` moves across the data-parallel mesh axes, so every
leaf's shape/dtype must be a pure function of ``(L, k)`` — never of the data.

Implemented codecs (paper Sec. 2.2 moves ``2·N·k`` words; these shrink the
constant in front):

* ``coo_fp32``      — fp32 values + int32 indices. The baseline wire format
  (exactly the pre-``repro.comm`` behavior): 64 bits/coordinate.
* ``coo_idx_delta`` — indices sorted ascending and delta-encoded. Sorted
  deltas are bounded by ``L - 1``, so the delta dtype is chosen *statically*
  from ``L`` (int8 for L < 2^7, int16 for L < 2^15, else int32 — no win).
  Lossless; 32 + 8/16 bits per coordinate on small/medium shards.
* ``bitmap_dense``  — a 1-bit presence bitmap (packed uint8) + the k values
  in index-ascending order. ``L + 32·k`` bits: beats COO's ``32·k`` index
  cost whenever S = k/L > 1/32.
* ``coo_q8``        — int8-quantized values (symmetric per-payload scale) +
  int32 indices. Lossy: the quantization residual must be folded back into
  the sparsifier's error accumulator ``eps`` (error feedback covers the
  codec); callers do that via :func:`decoded_dense` — see
  ``distributed._spa_leaf`` / ``simulator.step_fn``.

Round-trip contract: ``decode(encode(vals, idx)) == (vals', idx')`` such that
``scatter_add(vals', idx') == scatter_add(vals, idx)`` exactly for lossless
codecs (decode may reorder coordinates and merge duplicate padding slots).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Payload = Dict[str, jax.Array]


def _scatter_dense(vals: jax.Array, idx: jax.Array, length: int) -> jax.Array:
    return jnp.zeros((length,), vals.dtype).at[idx].add(vals)


class Codec:
    """Base codec. Subclasses set ``name``/``lossless`` and implement
    ``encode``/``decode``/``wire_bits``; codecs whose encoding is a pure
    function of the k selected ``(vals, idx)`` registers additionally
    implement :meth:`encode_fused` (and set ``supports_fused``) so the
    fused select→encode fastpath can emit their payload without any dense
    intermediate — see :mod:`repro.comm.fastpath`."""

    name: str = "base"
    lossless: bool = True
    supports_fused: bool = False

    def encode(self, vals: jax.Array, idx: jax.Array, length: int) -> Payload:
        raise NotImplementedError

    def encode_fused(
        self, vals: jax.Array, idx: jax.Array, length: int
    ) -> Payload:
        """Optional hook: encode straight from the fused pipeline's
        ``(vals [k], idx [k])`` output. Must produce a payload
        bit-identical to ``encode`` on the same inputs; the difference is
        the *contract* — no dense [L] intermediate may be touched, so the
        epilogue fuses behind the selection kernel. Codecs whose wire
        format is inherently dense (``bitmap_dense``) leave this
        unimplemented."""
        raise NotImplementedError(
            f"codec {self.name!r} has no fused encode epilogue"
        )

    def decode(
        self, payload: Payload, length: int
    ) -> Tuple[jax.Array, jax.Array]:
        """Returns ``(vals [k], idx [k])``; padding slots decode to (0, 0)."""
        raise NotImplementedError

    def wire_bits(self, length: int, k: int) -> int:
        """Exact payload size in bits — the codec's bit accounting."""
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def decoded_dense(self, payload: Payload, length: int) -> jax.Array:
        """Dense [L] view of what this payload actually carries. For lossy
        codecs this is what the receiver reconstructs — the sender folds
        ``intended - decoded_dense`` back into ``eps`` (error feedback)."""
        vals, idx = self.decode(payload, length)
        return _scatter_dense(vals, idx, length)


class CooFp32(Codec):
    """fp32 values + int32 indices — the uncompressed-index baseline."""

    name = "coo_fp32"
    lossless = True
    supports_fused = True

    def encode(self, vals, idx, length):
        return {"vals": vals.astype(jnp.float32), "idx": idx.astype(jnp.int32)}

    def encode_fused(self, vals, idx, length):
        """Pure register passthrough — the COO payload *is* the fused
        pipeline's output."""
        return self.encode(vals, idx, length)

    def decode(self, payload, length):
        return payload["vals"], payload["idx"]

    def wire_bits(self, length, k):
        return 32 * k + 32 * k


def delta_index_dtype(length: int):
    """Static dtype for sorted-index deltas: every delta (and the leading
    absolute index) is < ``length``, so the choice depends only on L.

    >>> delta_index_dtype(100) is jnp.int8
    True
    >>> delta_index_dtype(1 << 14) is jnp.int16
    True
    >>> delta_index_dtype(1 << 20) is jnp.int32
    True
    """
    if length < 2**7:
        return jnp.int8
    if length < 2**15:
        return jnp.int16
    return jnp.int32


class CooIdxDelta(Codec):
    """Sorted indices, delta-encoded in the narrowest statically-safe int."""

    name = "coo_idx_delta"
    lossless = True
    supports_fused = True

    def encode_fused(self, vals, idx, length):
        """k-sized sort + diff over the selected registers — O(k log k)
        epilogue work, no dense intermediate."""
        return self.encode(vals, idx, length)

    def encode(self, vals, idx, length):
        order = jnp.argsort(idx)
        si = idx[order].astype(jnp.int32)
        sv = vals[order].astype(jnp.float32)
        deltas = jnp.concatenate([si[:1], jnp.diff(si)])
        return {"vals": sv, "deltas": deltas.astype(delta_index_dtype(length))}

    def decode(self, payload, length):
        idx = jnp.cumsum(payload["deltas"].astype(jnp.int32))
        return payload["vals"], idx

    def wire_bits(self, length, k):
        return 32 * k + 8 * jnp.dtype(delta_index_dtype(length)).itemsize * k


def _pack_bits(mask: jax.Array) -> jax.Array:
    """{0,1} mask [L] -> packed uint8 [ceil(L/8)] (little-endian bit order)."""
    L = mask.shape[0]
    pad = (-L) % 8
    m = jnp.pad(mask.astype(jnp.uint8), (0, pad)).reshape(-1, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8)).astype(jnp.uint8)
    return (m * weights).sum(axis=1).astype(jnp.uint8)


def _unpack_bits(packed: jax.Array, length: int) -> jax.Array:
    bits = (packed[:, None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    return bits.reshape(-1)[:length].astype(jnp.float32)


class BitmapDense(Codec):
    """1-bit presence bitmap + values in index-ascending order.

    ``L + 32·k`` bits: wins over COO index lists when S = k/L > 1/32.
    Duplicate padding slots (idx 0, val 0) merge into the bitmap; the value
    vector is order-normalized, so decode returns coordinates ascending.
    """

    name = "bitmap_dense"
    lossless = True
    supports_fused = False  # wire format is inherently dense (RPL105)

    def encode(self, vals, idx, length):
        k = vals.shape[0]
        dense = _scatter_dense(vals.astype(jnp.float32), idx, length)
        mask = jnp.zeros((length,), jnp.float32).at[idx].set(1.0)
        rank = jnp.cumsum(mask).astype(jnp.int32) - 1
        slot = jnp.where(mask > 0, rank, k)  # k is out-of-bounds -> dropped
        packed_vals = (
            jnp.zeros((k,), jnp.float32)
            .at[slot]
            .set(dense, mode="drop")
        )
        return {"bitmap": _pack_bits(mask), "vals": packed_vals}

    def decode(self, payload, length):
        k = payload["vals"].shape[0]
        mask = _unpack_bits(payload["bitmap"], length)
        rank = jnp.cumsum(mask).astype(jnp.int32) - 1
        slot = jnp.where(mask > 0, rank, k)
        idx = (
            jnp.zeros((k,), jnp.int32)
            .at[slot]
            .set(jnp.arange(length, dtype=jnp.int32), mode="drop")
        )
        valid = jnp.arange(k) < mask.sum().astype(jnp.int32)
        return jnp.where(valid, payload["vals"], 0.0), jnp.where(valid, idx, 0)

    def wire_bits(self, length, k):
        return 8 * ((length + 7) // 8) + 32 * k


class CooQ8(Codec):
    """int8 symmetric quantization of the values; indices stay int32.

    Lossy: ``decode`` dequantizes with a per-payload fp32 scale. The caller
    must fold ``vals - decoded`` into the sparsifier's error accumulator so
    error feedback covers the codec (ISSUE tentpole; cf. 1-bit SGD / EF-SGD).
    """

    name = "coo_q8"
    lossless = False
    supports_fused = True

    def encode(self, vals, idx, length):
        amax = jnp.max(jnp.abs(vals))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(vals / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale, "idx": idx.astype(jnp.int32)}

    def encode_fused(self, vals, idx, length):
        """Quantization epilogue over the k selected registers: the
        per-payload amax/scale/round chain reads only the fused pipeline's
        output, so it fuses behind the selection kernel with no dense
        intermediate."""
        return self.encode(vals, idx, length)

    def decode(self, payload, length):
        vals = payload["q"].astype(jnp.float32) * payload["scale"]
        return vals, payload["idx"]

    def wire_bits(self, length, k):
        return 8 * k + 32 + 32 * k


CODECS = {
    c.name: c
    for c in (CooFp32(), CooIdxDelta(), BitmapDense(), CooQ8())
}


def get_codec(name: str) -> Codec:
    """Look up a registered codec by name.

    >>> get_codec("bitmap_dense").wire_bits(1024, 16)  # L + 32·k bits
    1536
    >>> get_codec("bogus")
    Traceback (most recent call last):
        ...
    ValueError: unknown codec 'bogus'; available: ['bitmap_dense', \
'coo_fp32', 'coo_idx_delta', 'coo_q8']
    """
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; available: {sorted(CODECS)}"
        ) from None
