"""Micro-calibration: time real collectives, fit the AlphaBeta link model.

The planner (:mod:`repro.comm.autotune`) is only as good as its alpha/beta.
This module probes the *actual* backend with raw collectives — a psum of a
dense [L] vector and an all_gather of a B-byte buffer over the dp axes —
at a geometric ladder of sizes, then least-squares fits

    seconds = n_messages * alpha + bytes_on_wire * beta

over the measured (n_messages, bytes_on_wire, seconds) samples, where the
message/byte counts come from the same ring patterns the cost model scores
(:func:`repro.comm.cost._pattern`). ``calibrate()`` is the one-call entry:
it builds a dp mesh over the available devices and returns a fitted
:class:`AlphaBeta` plus the raw samples; on a single device there is no
wire to probe and it falls back to the default model (``calibrated=False``).

Caveats (by design — this is a micro-harness, not a benchmark suite):
timings include shard_map dispatch overhead, so alpha absorbs the launch
cost; per-backend NCCL/ICI calibration with isolated link classes is the
ROADMAP follow-up.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.cost import AlphaBeta, _pattern
from repro.compat import make_mesh, shard_map

DEFAULT_LENGTHS = (1 << 12, 1 << 14, 1 << 16, 1 << 18)


@dataclasses.dataclass(frozen=True)
class Sample:
    """One timed collective: the fit's (features, target) row."""

    collective: str
    length: int
    n_messages: int
    bytes_on_wire: int
    seconds: float


@dataclasses.dataclass(frozen=True)
class Calibration:
    model: AlphaBeta
    samples: Tuple[Sample, ...]
    calibrated: bool
    residual: float  # RMS of the fit, seconds


def fit_alpha_beta(
    samples: Sequence[Sample],
    floor_alpha: float = 1e-9,
    floor_beta: float = 1e-14,
) -> AlphaBeta:
    """Non-negative least squares (clamped) over the sample rows."""
    if not samples:
        raise ValueError("cannot fit AlphaBeta from zero samples")
    A = np.array(
        [[s.n_messages, s.bytes_on_wire] for s in samples], np.float64
    )
    t = np.array([s.seconds for s in samples], np.float64)
    x, *_ = np.linalg.lstsq(A, t, rcond=None)
    alpha, beta = float(x[0]), float(x[1])
    # a negative coefficient means the other term explains everything at
    # these sizes; clamp and refit the remaining term alone.
    if alpha < floor_alpha and beta < floor_beta:
        return AlphaBeta(alpha=floor_alpha, beta=floor_beta)
    if alpha < floor_alpha:
        beta = max(float(t @ A[:, 1] / (A[:, 1] @ A[:, 1])), floor_beta)
        return AlphaBeta(alpha=floor_alpha, beta=beta)
    if beta < floor_beta:
        alpha = max(float(t @ A[:, 0] / (A[:, 0] @ A[:, 0])), floor_alpha)
        return AlphaBeta(alpha=alpha, beta=floor_beta)
    return AlphaBeta(alpha=alpha, beta=beta)


def _time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_collective(
    mesh,
    dp_axes: Sequence[str],
    length: int,
    collective: str = "dense_allreduce",
    word_bytes: int = 4,
    iters: int = 5,
) -> Sample:
    """Time one real collective over the mesh's dp axes.

    ``dense_allreduce`` psums a dense float32 [L]; ``sparse_allgather``
    all_gathers a ``length``-word buffer (the payload stand-in — the wire
    doesn't care what the words mean).
    """
    dp = tuple(dp_axes)
    dp_spec = dp if len(dp) > 1 else dp[0]
    W = int(np.prod([mesh.shape[a] for a in dp]))

    if collective == "dense_allreduce":

        def body(x):  # x local [1, L]
            return jax.lax.psum(x, dp)

        out_spec = P(None, None)
        payload_bytes = 0.0  # dense term carries the bytes
    elif collective == "sparse_allgather":

        def body(x):  # x local [1, L] -> gathered [W, L], reduced locally
            g = x
            for ax in dp:
                g = jax.lax.all_gather(g, ax)
            return g.reshape(-1, x.shape[-1]).sum(axis=0, keepdims=True)

        out_spec = P(None, None)
        payload_bytes = length * word_bytes
    else:
        raise ValueError(
            f"calibration probe for {collective!r} not implemented; "
            "use 'dense_allreduce' or 'sparse_allgather'"
        )

    f = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=P(dp_spec, None),
            out_specs=out_spec,
            check_vma=False,
        )
    )
    x = jnp.ones((W, length), jnp.float32)
    secs = _time_call(f, x, iters=iters)
    dp_sizes = [mesh.shape[a] for a in dp]
    by, msgs = _pattern(collective, length, payload_bytes, dp_sizes, word_bytes)
    return Sample(
        collective=collective,
        length=length,
        n_messages=msgs,
        bytes_on_wire=int(np.ceil(by)),
        seconds=secs,
    )


def calibrate(
    mesh=None,
    dp_axes: Optional[Sequence[str]] = None,
    lengths: Sequence[int] = DEFAULT_LENGTHS,
    collectives: Sequence[str] = ("dense_allreduce", "sparse_allgather"),
    iters: int = 5,
) -> Calibration:
    """Probe the backend and fit AlphaBeta. A dp group of fewer than two
    workers (single device, or a caller mesh with dp size 1) has no wire to
    probe: every sample row would be (0 messages, 0 bytes) and the fit
    degenerates to the clamp floors — fall back to the default model."""
    if mesh is None:
        n = len(jax.devices())
        if n >= 2:
            mesh = make_mesh((n,), ("data",))
            dp_axes = ("data",)
    dp_axes = tuple(dp_axes or ("data",))
    n_dp = (
        int(np.prod([mesh.shape[a] for a in dp_axes])) if mesh is not None
        else 1
    )
    if n_dp < 2:
        return Calibration(
            model=AlphaBeta(), samples=(), calibrated=False, residual=0.0
        )
    samples: List[Sample] = []
    for coll in collectives:
        for L in lengths:
            samples.append(
                time_collective(mesh, dp_axes, L, coll, iters=iters)
            )
    model = fit_alpha_beta(samples)
    pred = np.array(
        [s.n_messages * model.alpha + s.bytes_on_wire * model.beta
         for s in samples]
    )
    meas = np.array([s.seconds for s in samples])
    rms = float(np.sqrt(np.mean((pred - meas) ** 2)))
    return Calibration(
        model=model, samples=tuple(samples), calibrated=True, residual=rms
    )
