"""Micro-calibration: time real collectives, fit alpha–beta link models.

The planner (:mod:`repro.comm.autotune`) is only as good as its alpha/beta.
This module probes the *actual* backend with raw collectives — a psum of a
dense [L] vector and an all_gather of a B-byte buffer over the dp axes —
at a geometric ladder of sizes, then least-squares fits

    seconds = n_messages * alpha + bytes_on_wire * beta

over the measured (n_messages, bytes_on_wire, seconds) samples, where the
message/byte counts come from the same ring patterns the cost model scores
(:func:`repro.comm.cost._pattern`). Two entry points:

* ``calibrate()`` — one :class:`AlphaBeta` for the whole dp group: builds a
  dp mesh over the available devices and returns the fitted model plus the
  raw samples; on a single device there is no wire to probe and it falls
  back to the default model (``calibrated=False``).
* ``calibrate_topo()`` — one :class:`AlphaBeta` *per dp mesh axis*: probes
  collectives along each axis separately (the other axes stay idle), so an
  intra-node NVLink/ICI axis and an inter-node NIC axis each get their own
  fit. The result's :class:`~repro.comm.cost.LinkTopo` drops straight into
  ``DistConfig.link_topo`` / the planner's ``model=`` argument.

Caveats (by design — this is a micro-harness, not a benchmark suite):
timings include shard_map dispatch overhead, so alpha absorbs the launch
cost, and per-axis probes time each link class under an otherwise-idle
mesh (no congestion between classes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.cost import AlphaBeta, LinkTopo, _pattern
from repro.compat import make_mesh, shard_map

DEFAULT_LENGTHS = (1 << 12, 1 << 14, 1 << 16, 1 << 18)


def _resolve_mesh(mesh, dp_axes):
    """Default mesh/axes discovery shared by the calibrate entry points:
    with no mesh, probe all local devices on one ("data",) axis. A caller
    supplying ``dp_axes`` without the mesh that defines them is ambiguous
    — refuse rather than silently probing a different topology."""
    if mesh is None:
        if dp_axes is not None:
            raise ValueError(
                "dp_axes without a mesh is ambiguous: pass the mesh whose "
                f"axes {tuple(dp_axes)} should be probed"
            )
        n = len(jax.devices())
        if n >= 2:
            mesh = make_mesh((n,), ("data",))
        return mesh, ("data",)
    return mesh, tuple(dp_axes or ("data",))


@dataclasses.dataclass(frozen=True)
class Sample:
    """One timed collective: the fit's (features, target) row."""

    collective: str
    length: int
    n_messages: int
    bytes_on_wire: int
    seconds: float


@dataclasses.dataclass(frozen=True)
class Calibration:
    model: AlphaBeta
    samples: Tuple[Sample, ...]
    calibrated: bool
    residual: float  # RMS of the fit, seconds


@dataclasses.dataclass(frozen=True)
class TopoCalibration:
    """Per-axis calibrations plus the :class:`LinkTopo` they assemble into.

    ``axes`` names the dp mesh axes (outermost first); ``per_axis[i]`` is
    that axis's own :class:`Calibration` (``calibrated=False`` for size-1
    axes, which have no wire to probe). ``calibrated`` is True when at
    least one axis was actually timed.
    """

    topo: LinkTopo
    per_axis: Tuple[Calibration, ...]
    axes: Tuple[str, ...]
    calibrated: bool


def fit_alpha_beta(
    samples: Sequence[Sample],
    floor_alpha: float = 1e-9,
    floor_beta: float = 1e-14,
) -> AlphaBeta:
    """Non-negative least squares (clamped) over the sample rows.

    >>> rows = [Sample("probe", i, m, b, m * 2e-5 + b * 3e-10)
    ...         for i, (m, b) in enumerate([(7, 1000), (14, 100000),
    ...                                     (3, 5000000)])]
    >>> fit = fit_alpha_beta(rows)
    >>> round(fit.alpha, 9), round(fit.beta, 14)
    (2e-05, 3e-10)
    """
    if not samples:
        raise ValueError("cannot fit AlphaBeta from zero samples")
    A = np.array(
        [[s.n_messages, s.bytes_on_wire] for s in samples], np.float64
    )
    t = np.array([s.seconds for s in samples], np.float64)
    x, *_ = np.linalg.lstsq(A, t, rcond=None)
    alpha, beta = float(x[0]), float(x[1])
    # a negative coefficient means the other term explains everything at
    # these sizes; clamp and refit the remaining term alone.
    if alpha < floor_alpha and beta < floor_beta:
        return AlphaBeta(alpha=floor_alpha, beta=floor_beta)
    if alpha < floor_alpha:
        beta = max(float(t @ A[:, 1] / (A[:, 1] @ A[:, 1])), floor_beta)
        return AlphaBeta(alpha=floor_alpha, beta=beta)
    if beta < floor_beta:
        alpha = max(float(t @ A[:, 0] / (A[:, 0] @ A[:, 0])), floor_alpha)
        return AlphaBeta(alpha=alpha, beta=floor_beta)
    return AlphaBeta(alpha=alpha, beta=beta)


def _time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_collective(
    mesh,
    dp_axes: Sequence[str],
    length: int,
    collective: str = "dense_allreduce",
    word_bytes: int = 4,
    iters: int = 5,
) -> Sample:
    """Time one real collective over the mesh's dp axes.

    ``dense_allreduce`` psums a dense float32 [L]; ``sparse_allgather``
    all_gathers a ``length``-word buffer (the payload stand-in — the wire
    doesn't care what the words mean).

    >>> s = time_collective(mesh, ("data",), 4096)  # doctest: +SKIP
    >>> s.n_messages  # 2·(N-1) ring steps          # doctest: +SKIP
    14
    """
    dp = tuple(dp_axes)
    dp_spec = dp if len(dp) > 1 else dp[0]
    W = int(np.prod([mesh.shape[a] for a in dp]))

    if collective == "dense_allreduce":

        def body(x):  # x local [1, L]
            return jax.lax.psum(x, dp)

        out_spec = P(None, None)
        payload_bytes = 0.0  # dense term carries the bytes
    elif collective == "sparse_allgather":

        def body(x):  # x local [1, L] -> gathered [W, L], reduced locally
            g = x
            for ax in dp:
                g = jax.lax.all_gather(g, ax)
            return g.reshape(-1, x.shape[-1]).sum(axis=0, keepdims=True)

        out_spec = P(None, None)
        payload_bytes = length * word_bytes
    else:
        raise ValueError(
            f"calibration probe for {collective!r} not implemented; "
            "use 'dense_allreduce' or 'sparse_allgather'"
        )

    f = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=P(dp_spec, None),
            out_specs=out_spec,
            check_vma=False,
        )
    )
    x = jnp.ones((W, length), jnp.float32)
    secs = _time_call(f, x, iters=iters)
    dp_sizes = [mesh.shape[a] for a in dp]
    by, msgs = _pattern(collective, length, payload_bytes, dp_sizes, word_bytes)
    return Sample(
        collective=collective,
        length=length,
        n_messages=msgs,
        bytes_on_wire=int(np.ceil(by)),
        seconds=secs,
    )


def calibrate(
    mesh=None,
    dp_axes: Optional[Sequence[str]] = None,
    lengths: Sequence[int] = DEFAULT_LENGTHS,
    collectives: Sequence[str] = ("dense_allreduce", "sparse_allgather"),
    iters: int = 5,
) -> Calibration:
    """Probe the backend and fit AlphaBeta. A dp group of fewer than two
    workers (single device, or a caller mesh with dp size 1) has no wire to
    probe: every sample row would be (0 messages, 0 bytes) and the fit
    degenerates to the clamp floors — fall back to the default model.

    >>> from repro.compat import make_mesh
    >>> res = calibrate(mesh=make_mesh((1,), ("data",)), dp_axes=("data",))
    >>> res.calibrated, res.model == AlphaBeta()
    (False, True)
    """
    mesh, dp_axes = _resolve_mesh(mesh, dp_axes)
    n_dp = (
        int(np.prod([mesh.shape[a] for a in dp_axes])) if mesh is not None
        else 1
    )
    if n_dp < 2:
        return Calibration(
            model=AlphaBeta(), samples=(), calibrated=False, residual=0.0
        )
    samples: List[Sample] = []
    for coll in collectives:
        for L in lengths:
            samples.append(
                time_collective(mesh, dp_axes, L, coll, iters=iters)
            )
    model = fit_alpha_beta(samples)
    pred = np.array(
        [s.n_messages * model.alpha + s.bytes_on_wire * model.beta
         for s in samples]
    )
    meas = np.array([s.seconds for s in samples])
    rms = float(np.sqrt(np.mean((pred - meas) ** 2)))
    return Calibration(
        model=model, samples=tuple(samples), calibrated=True, residual=rms
    )


def calibrate_topo(
    mesh=None,
    dp_axes: Optional[Sequence[str]] = None,
    lengths: Sequence[int] = DEFAULT_LENGTHS,
    collectives: Sequence[str] = ("dense_allreduce", "sparse_allgather"),
    iters: int = 5,
) -> TopoCalibration:
    """Fit one :class:`AlphaBeta` *per dp mesh axis* by timing collectives
    along each axis separately (the other axes sit idle), assembling a
    :class:`~repro.comm.cost.LinkTopo` ordered like ``dp_axes`` (outermost
    first). Size-1 axes have no wire to probe and keep the default model
    with ``calibrated=False`` in their per-axis entry.

    With no mesh given, mirrors :func:`calibrate`'s device discovery: all
    local devices on one ``("data",)`` axis — per-axis calibration then
    degenerates to the uniform fit. Pass the real training mesh (e.g.
    ``("pod", "data")``) to resolve distinct link classes.

    >>> from repro.compat import make_mesh
    >>> res = calibrate_topo(mesh=make_mesh((1, 1), ("pod", "data")),
    ...                      dp_axes=("pod", "data"))
    >>> res.calibrated, res.topo.n_axes
    (False, 2)
    """
    mesh, dp_axes = _resolve_mesh(mesh, dp_axes)
    per_axis: List[Calibration] = []
    for ax in dp_axes:
        size = mesh.shape[ax] if mesh is not None else 1
        if size < 2:
            per_axis.append(
                Calibration(
                    model=AlphaBeta(), samples=(), calibrated=False,
                    residual=0.0,
                )
            )
            continue
        per_axis.append(
            calibrate(
                mesh=mesh,
                dp_axes=(ax,),
                lengths=lengths,
                collectives=collectives,
                iters=iters,
            )
        )
    return TopoCalibration(
        topo=LinkTopo(tuple(c.model for c in per_axis)),
        per_axis=tuple(per_axis),
        axes=dp_axes,
        calibrated=any(c.calibrated for c in per_axis),
    )
