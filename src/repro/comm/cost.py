"""Communication cost accounting: per-link-class alpha–beta model + measured bytes.

Two views of every round, per (codec, collective, mesh):

* ``predicted_bytes`` / ``predict`` — the analytic alpha–beta model
  (latency ``alpha`` per message + ``beta`` seconds/byte), computed from the
  codec's exact ``wire_bits`` accounting and the collective's communication
  pattern.
* ``measured_bytes`` — the same pattern costed with the *actual* encoded
  buffer sizes (``payload_nbytes`` over the payload pytree). Because all
  payload shapes are static, this is exact, and benchmarks assert
  ``measured <= predicted * 1.05``.

Patterns (per-worker, per-round, ring realizations):

* ``dense_allreduce``  — ring allreduce of the dense [L] vector:
  ``2·(N-1)/N·L·word`` bytes, ``2·(N-1)`` messages.
* ``sparse_allgather`` — ring allgather of the payload: ``(N-1)·payload``
  bytes received, ``N-1`` messages.
* ``hierarchical``     — allgather over the inter axes (``(B-1)·payload``)
  + dense ring allreduce over the intra axis (``2·(A-1)/A·L·word``).

Link models — scalar and per-link-class:

* :class:`AlphaBeta` — one (alpha, beta) for every link in the mesh.
* :class:`LinkTopo`  — one :class:`AlphaBeta` *per dp mesh axis*, ordered
  outermost (slowest) first, matching the repo's mesh convention
  (``dp_axes=("pod", "data")``: inter-pod NICs then intra-pod ICI).

Per-axis attribution (:func:`pattern_axes`): every collective decomposes
into per-axis (bytes, messages) contributions summing exactly to the flat
pattern, and ``seconds = sum_axis msgs_a * alpha_a + bytes_a * beta_a``. A
ring that spans *several* axes at once (``dense_allreduce`` and
``sparse_allgather`` over a multi-axis dp group) is synchronous: every step
is gated by the slowest link it crosses, which under the outermost-first
ordering is the outermost axis *with more than one worker* — so flat
stages charge that axis (size-1 axes carry no traffic and price nothing),
while ``hierarchical``'s intra stage runs (and is priced) on the
innermost axis alone. With a uniform :class:`LinkTopo` this reproduces the
scalar :class:`AlphaBeta` predictions bit-for-bit; with a slow outer axis
it is what makes ``hierarchical`` strictly preferable at all (see
``docs/comm.md`` for the uniform-model impossibility proof).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.comm.codec import Codec, Payload, get_codec

WORD_BYTES = 4  # fp32 words, the dense baseline unit


@dataclasses.dataclass(frozen=True)
class AlphaBeta:
    """Classic LogP-style link model: ``alpha`` s/message, ``beta`` s/byte.

    Defaults approximate a datacenter NIC: 10 us latency, 100 GB/s links.

    >>> AlphaBeta().alpha
    1e-05
    >>> AlphaBeta(alpha=2e-6, beta=5e-12)
    AlphaBeta(alpha=2e-06, beta=5e-12)
    """

    alpha: float = 1e-5
    beta: float = 1e-11


@dataclasses.dataclass(frozen=True)
class LinkTopo:
    """Per-mesh-axis link topology: ``links[i]`` prices traffic attributed
    to dp mesh axis ``i``, outermost (slowest) first — the same ordering as
    ``dp_sizes`` / ``DistConfig.dp_axes``.

    A 2-pod mesh with slow inter-pod NICs and fast intra-pod ICI:

    >>> topo = LinkTopo((AlphaBeta(1e-5, 1e-10), AlphaBeta(1e-6, 1e-11)))
    >>> topo.n_axes
    2
    >>> topo.uniform(AlphaBeta(), 2) == LinkTopo((AlphaBeta(), AlphaBeta()))
    True
    """

    links: Tuple[AlphaBeta, ...]

    def __post_init__(self):
        links = tuple(self.links)
        if not links:
            raise ValueError("LinkTopo needs at least one per-axis link")
        if not all(isinstance(l, AlphaBeta) for l in links):
            raise TypeError("LinkTopo.links must be AlphaBeta instances")
        object.__setattr__(self, "links", links)

    @classmethod
    def uniform(cls, model: AlphaBeta, n_axes: int) -> "LinkTopo":
        """One identical link class for every axis — reproduces the scalar
        :class:`AlphaBeta` predictions bit-for-bit (see :func:`predict`)."""
        return cls((model,) * int(n_axes))

    @property
    def n_axes(self) -> int:
        return len(self.links)

    @property
    def is_uniform(self) -> bool:
        return all(l == self.links[0] for l in self.links)


LinkModel = Union[AlphaBeta, LinkTopo]


def as_topo(model: LinkModel, n_axes: int) -> LinkTopo:
    """Normalize a link model to a :class:`LinkTopo` over ``n_axes`` axes.

    A scalar :class:`AlphaBeta` broadcasts uniformly; a :class:`LinkTopo`
    must already match the dp mesh rank exactly.

    >>> as_topo(AlphaBeta(), 2).n_axes
    2
    >>> as_topo(LinkTopo.uniform(AlphaBeta(), 3), 2)
    Traceback (most recent call last):
        ...
    ValueError: LinkTopo has 3 per-axis links but the dp mesh has 2 axes
    """
    if isinstance(model, LinkTopo):
        if model.n_axes != n_axes:
            raise ValueError(
                f"LinkTopo has {model.n_axes} per-axis links but the dp "
                f"mesh has {n_axes} axes"
            )
        return model
    if isinstance(model, AlphaBeta):
        return LinkTopo.uniform(model, n_axes)
    raise TypeError(f"expected AlphaBeta or LinkTopo, got {type(model)!r}")


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    bytes_on_wire: int  # per worker per round
    n_messages: int
    seconds: float


def payload_nbytes(payload: Payload) -> int:
    """Actual buffer bytes of one encoded payload (static shapes).

    >>> import jax.numpy as jnp
    >>> payload_nbytes({"vals": jnp.zeros((16,), jnp.float32),
    ...                 "idx": jnp.zeros((16,), jnp.int32)})
    128
    """
    return int(
        sum(
            int(np.prod(x.shape)) * jax.dtypes.canonicalize_dtype(
                x.dtype
            ).itemsize
            for x in jax.tree.leaves(payload)
        )
    )


def _ring_fraction(
    dp_sizes: Sequence[int], participants: Optional[float]
) -> float:
    """Participating fraction ``f`` of the *hop count* of each ring stage:
    a group of size ``g`` shrinks to ``1 + (g - 1) * f`` effective members
    (exact at full participation, never below one, strictly smaller on any
    stage with >1 worker when ``f < 1``)."""
    if participants is None:
        return 1.0
    n = int(np.prod([int(s) for s in dp_sizes])) or 1
    if not 1.0 <= float(participants) <= n:
        raise ValueError(
            f"participants={participants} outside [1, {n}] for dp mesh "
            f"{tuple(dp_sizes)}"
        )
    return (float(participants) - 1.0) / max(n - 1, 1) if n > 1 else 1.0


def pattern_axes(
    collective: str,
    length: int,
    payload_bytes: float,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
) -> Tuple[Tuple[float, int], ...]:
    """Per-axis ``(bytes, messages)`` contributions for one worker, one
    round — aligned with ``dp_sizes`` (outermost first) and summing exactly
    to the flat pattern.

    Flat rings spanning several axes (``dense_allreduce``,
    ``sparse_allgather``, and ``hierarchical``'s inter-axis allgather when
    there are multiple outer axes) are synchronous: each step is gated by
    the slowest link crossed, i.e. the outermost axis *with more than one
    worker* in the span under the slowest-first mesh ordering (size-1 axes
    carry no traffic and must not price anything) — the whole stage is
    attributed to that axis. ``hierarchical``'s intra-axis dense allreduce
    runs on the innermost axis alone.

    ``participants`` prices a *partial* round (straggler-dropping
    schedules, see :mod:`repro.comm.participation`): it is the expected
    number of on-time workers over the whole flat group, and a ring
    stage's group size ``g`` shrinks proportionally to
    ``1 + (g - 1) * (participants - 1)/(N - 1)`` — fewer hops, so fewer
    messages and bytes on the charged axes, strictly so whenever
    ``participants < N`` on a stage with more than one worker. The one
    exception is ``hierarchical``'s intra-axis allreduce when the mesh
    has ``B > 1`` inter-axis groups: it runs as ``B`` parallel rings and
    the synchronous round is gated by the fullest of them, so that stage
    stays full-size (with ``B == 1`` it is the same single ring
    ``dense_allreduce`` prices, and shrinks identically). ``None`` (or
    ``participants == N``) reproduces the full-round pattern exactly.
    S-of-N client sampling (``Participation(kind="sampled")``) prices the
    same way with ``participants = S``: only the sampled subset puts
    payloads on the wire, so an S-of-2000 round costs like an S-worker
    ring, not a 2000-worker one.

    >>> pattern_axes("hierarchical", 1024, 128.0, (2, 4))
    ((128.0, 1), (6144.0, 6))
    >>> pattern_axes("sparse_allgather", 1024, 128.0, (2, 4))
    ((896.0, 7), (0.0, 0))
    >>> pattern_axes("sparse_allgather", 1024, 128.0, (1, 4))
    ((0.0, 0), (384.0, 3))
    >>> pattern_axes("sparse_allgather", 1024, 128.0, (8,), participants=4.5)
    ((448.0, 4),)
    """
    sizes = [int(s) for s in dp_sizes] or [1]
    m = len(sizes)
    n = int(np.prod(sizes))
    f = _ring_fraction(sizes, participants)
    zero = [(0.0, 0)] * m

    def gate(span_sizes):
        # outermost axis that actually has workers: the slowest link the
        # flat ring crosses (a size-1 axis contributes no hops)
        for i, s in enumerate(span_sizes):
            if s > 1:
                return i
        return 0

    def allreduce_stage(g: int, frac: float = f):
        # effective ring-group size under partial participation
        p = 1.0 + (g - 1) * frac
        return (
            2.0 * (p - 1) / max(p, 1.0) * length * word_bytes,
            math.ceil(2 * (p - 1) - 1e-9) if p > 1 else 0,
        )

    def gather_stage(g: int):
        p = 1.0 + (g - 1) * f
        return (
            (p - 1) * payload_bytes,
            math.ceil(p - 1 - 1e-9) if p > 1 else 0,
        )

    if collective == "dense_allreduce":
        zero[gate(sizes)] = allreduce_stage(n)
        return tuple(zero)
    if collective == "sparse_allgather":
        zero[gate(sizes)] = gather_stage(n)
        return tuple(zero)
    if collective == "hierarchical":
        # last dp axis = intra (fast, dense allreduce); outer axes = inter
        # (slow, compressed payload allgather) — matches Hierarchical.shard.
        # Participation shrinks the inter gather; the intra stage is B
        # parallel rings and the synchronous round is gated by the
        # fullest of them, so with B > 1 it is priced full-size (a
        # straggler thins one ring, not the critical-path one). With
        # B == 1 there is a single ring — the same ring dense_allreduce
        # prices — and it shrinks identically.
        a = sizes[-1]
        b = int(np.prod(sizes[:-1])) if m > 1 else 1
        inter = gather_stage(b)
        intra = allreduce_stage(a, frac=1.0 if b > 1 else f)
        if m == 1:
            return ((inter[0] + intra[0], inter[1] + intra[1]),)
        zero[gate(sizes[:-1])], zero[-1] = inter, intra
        return tuple(zero)
    raise ValueError(f"unknown collective {collective!r}")


def _pattern(
    collective: str,
    length: int,
    payload_bytes: float,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
):
    """(bytes, messages) for one worker, one round — the per-axis sums."""
    per_axis = pattern_axes(
        collective, length, payload_bytes, dp_sizes, word_bytes, participants
    )
    by = 0.0
    msgs = 0
    for b, g in per_axis:
        by += b
        msgs += g
    return by, msgs


def predicted_bytes(
    codec: Codec | str,
    collective: str,
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
) -> int:
    """Per-worker bytes/round from the codec's exact bit accounting.
    ``word_bytes`` sizes the dense terms (4 for fp32, 2 for bf16 state);
    ``participants`` prices a partial-participation round (see
    :func:`pattern_axes`).

    >>> predicted_bytes("coo_fp32", "sparse_allgather", 1024, 16, (8,))
    896
    >>> predicted_bytes("coo_fp32", "sparse_allgather", 1024, 16, (8,),
    ...                 participants=4.5)
    448
    """
    c = get_codec(codec) if isinstance(codec, str) else codec
    pb = math.ceil(int(c.wire_bits(length, k)) / 8)
    by, _ = _pattern(
        collective, length, pb, dp_sizes, word_bytes, participants
    )
    return math.ceil(by)


def measured_bytes(
    collective: str,
    length: int,
    payload: Payload,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
) -> int:
    """Per-worker bytes/round from the *actual* encoded buffers.

    Always a full-round figure: the SPMD collectives move every worker's
    (possibly zero-masked) full-size buffer whatever the participation
    schedule, so partial-round pricing lives on the *predicted* side only
    (:func:`predicted_bytes` / :func:`predict` ``participants=``).

    >>> import jax.numpy as jnp
    >>> payload = {"vals": jnp.zeros((16,), jnp.float32),
    ...            "idx": jnp.zeros((16,), jnp.int32)}
    >>> measured_bytes("sparse_allgather", 1024, payload, (8,))
    896
    """
    by, _ = _pattern(
        collective, length, payload_nbytes(payload), dp_sizes, word_bytes
    )
    return math.ceil(by)


def predict(
    codec: Codec | str,
    collective: str,
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    model: LinkModel = AlphaBeta(),
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
) -> CostEstimate:
    """Alpha–beta cost of one round: bytes, messages and predicted seconds.

    ``model`` is either a scalar :class:`AlphaBeta` (every link identical)
    or a :class:`LinkTopo` with one link class per dp mesh axis; the
    per-axis contributions come from :func:`pattern_axes` and

        ``seconds = sum_axis msgs_a * alpha_a + bytes_a * beta_a``.

    ``participants`` prices a partial-participation round — fewer ring
    hops, so strictly fewer bytes and messages on any charged axis with
    more than one worker (see :func:`pattern_axes`).

    A uniform topology is bit-for-bit identical to the scalar model:

    >>> uni = predict("coo_fp32", "sparse_allgather", 1024, 16, (2, 4))
    >>> topo = LinkTopo.uniform(AlphaBeta(), 2)
    >>> predict("coo_fp32", "sparse_allgather", 1024, 16, (2, 4), topo) == uni
    True

    A slow outer axis penalizes the flat collectives but only the (tiny)
    payload stage of ``hierarchical``:

    >>> slow_outer = LinkTopo((AlphaBeta(1e-5, 1e-9), AlphaBeta(1e-6, 1e-11)))
    >>> h = predict("coo_fp32", "hierarchical", 10**6, 10**5, (2, 4), slow_outer)
    >>> g = predict("coo_fp32", "sparse_allgather", 10**6, 10**5, (2, 4), slow_outer)
    >>> h.seconds < g.seconds
    True
    """
    c = get_codec(codec) if isinstance(codec, str) else codec
    pb = math.ceil(int(c.wire_bits(length, k)) / 8)
    per_axis = pattern_axes(
        collective, length, pb, dp_sizes, word_bytes, participants
    )
    by = 0.0
    msgs = 0
    for b, g in per_axis:
        by += b
        msgs += g
    topo = as_topo(model, len(per_axis))
    if topo.is_uniform:
        # scalar path, kept verbatim so a uniform LinkTopo reproduces the
        # historical AlphaBeta numbers bit-for-bit (same fp operation order)
        link = topo.links[0]
        seconds = msgs * link.alpha + by * link.beta
    else:
        seconds = sum(
            g * l.alpha + b * l.beta
            for (b, g), l in zip(per_axis, topo.links, strict=True)
        )
    return CostEstimate(
        bytes_on_wire=math.ceil(by),
        n_messages=msgs,
        seconds=seconds,
    )


def stage_seconds(
    codec: Codec | str,
    collective: str,
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    model: LinkModel = AlphaBeta(),
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
) -> Tuple[float, ...]:
    """Per-axis stage seconds of one leaf's round, aligned with
    ``dp_sizes`` (outermost first) — the decomposition the bucketed
    overlap scheduler (:mod:`repro.comm.overlap`) pipelines: the last
    entry is the innermost (intra) stage, everything before it the outer
    (inter) stages.

    Each axis is priced independently from its :func:`pattern_axes`
    contribution (``msgs_a * alpha_a + bytes_a * beta_a``), so the tuple
    sums to :func:`predict`'s ``seconds`` — exactly on a heterogeneous
    topology, and to fp summation order on a uniform one (where
    :func:`predict` keeps the historical scalar operation order).

    >>> slow_outer = LinkTopo((AlphaBeta(1e-5, 1e-9), AlphaBeta(1e-6, 1e-10)))
    >>> ax = stage_seconds("coo_fp32", "hierarchical", 10**6, 10**5,
    ...                    (2, 4), slow_outer)
    >>> len(ax)
    2
    >>> est = predict("coo_fp32", "hierarchical", 10**6, 10**5, (2, 4),
    ...               slow_outer)
    >>> sum(ax) == est.seconds
    True
    """
    c = get_codec(codec) if isinstance(codec, str) else codec
    pb = math.ceil(int(c.wire_bits(length, k)) / 8)
    per_axis = pattern_axes(
        collective, length, pb, dp_sizes, word_bytes, participants
    )
    topo = as_topo(model, len(per_axis))
    return tuple(
        g * lk.alpha + b * lk.beta
        for (b, g), lk in zip(per_axis, topo.links, strict=True)
    )


def parse_link_topo(spec: str, dp_axes: Sequence[str]) -> LinkTopo:
    """Parse a CLI link-topology spec into a :class:`LinkTopo` over
    ``dp_axes`` (outermost first).

    Grammar: ``;``-separated ``name:alpha,beta`` entries, where ``name`` is
    a dp mesh axis name or one of the aliases ``intra`` (the innermost dp
    axis) and ``inter`` (every outer axis). A bare ``alpha,beta`` with no
    name is uniform across all axes. Every axis must be covered exactly
    once.

    >>> parse_link_topo("inter:1e-5,1e-10;intra:1e-6,1e-11",
    ...                 ("pod", "data")).links
    (AlphaBeta(alpha=1e-05, beta=1e-10), AlphaBeta(alpha=1e-06, beta=1e-11))
    >>> parse_link_topo("2e-5,3e-11", ("data",))
    LinkTopo(links=(AlphaBeta(alpha=2e-05, beta=3e-11),))
    """
    axes = tuple(dp_axes)
    if not axes:
        raise ValueError("parse_link_topo needs at least one dp axis")
    spec = spec.strip()
    if not spec:
        raise ValueError("empty --link-topo spec")
    if ":" not in spec:
        model = _parse_alpha_beta(spec)
        return LinkTopo.uniform(model, len(axes))
    assigned: dict = {}
    for entry in spec.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        name, _, ab = entry.partition(":")
        name = name.strip()
        model = _parse_alpha_beta(ab)
        if name == "intra":
            targets = (axes[-1],)
        elif name == "inter":
            targets = axes[:-1]
            if not targets:
                raise ValueError(
                    "link class 'inter' given but the dp mesh "
                    f"{list(axes)} has no outer axes"
                )
        elif name in axes:
            targets = (name,)
        else:
            raise ValueError(
                f"unknown link class {name!r}; expected a dp axis name in "
                f"{list(axes)} or 'intra'/'inter'"
            )
        for t in targets:
            if t in assigned:
                raise ValueError(f"dp axis {t!r} assigned twice in {spec!r}")
            assigned[t] = model
    missing = [a for a in axes if a not in assigned]
    if missing:
        raise ValueError(f"dp axes {missing} not covered by {spec!r}")
    return LinkTopo(tuple(assigned[a] for a in axes))


def _parse_alpha_beta(ab: str) -> AlphaBeta:
    parts = [p.strip() for p in ab.split(",")]
    if len(parts) != 2:
        raise ValueError(
            f"expected 'alpha,beta' (seconds/message, seconds/byte), "
            f"got {ab!r}"
        )
    return AlphaBeta(alpha=float(parts[0]), beta=float(parts[1]))
