"""Communication cost accounting: alpha–beta model + measured bytes.

Two views of every round, per (codec, collective, mesh):

* ``predicted_bytes`` / ``predict`` — the analytic alpha–beta model
  (latency ``alpha`` per message + ``beta`` seconds/byte), computed from the
  codec's exact ``wire_bits`` accounting and the collective's communication
  pattern. This generalizes the old ``aggregate.wire_words_per_worker``.
* ``measured_bytes`` — the same pattern costed with the *actual* encoded
  buffer sizes (``payload_nbytes`` over the payload pytree). Because all
  payload shapes are static, this is exact, and benchmarks assert
  ``measured <= predicted * 1.05``.

Patterns (per-worker, per-round, ring realizations):

* ``dense_allreduce``  — ring allreduce of the dense [L] vector:
  ``2·(N-1)/N·L·word`` bytes, ``2·(N-1)`` messages.
* ``sparse_allgather`` — ring allgather of the payload: ``(N-1)·payload``
  bytes received, ``N-1`` messages.
* ``hierarchical``     — allgather over the inter axes (``(B-1)·payload``)
  + dense ring allreduce over the intra axis (``2·(A-1)/A·L·word``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import numpy as np

from repro.comm.codec import Codec, Payload, get_codec

WORD_BYTES = 4  # fp32 words, the dense baseline unit


@dataclasses.dataclass(frozen=True)
class AlphaBeta:
    """Classic LogP-style link model: ``alpha`` s/message, ``beta`` s/byte.

    Defaults approximate a datacenter NIC: 10 us latency, 100 GB/s links.
    """

    alpha: float = 1e-5
    beta: float = 1e-11


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    bytes_on_wire: int  # per worker per round
    n_messages: int
    seconds: float


def payload_nbytes(payload: Payload) -> int:
    """Actual buffer bytes of one encoded payload (static shapes)."""
    return int(
        sum(
            int(np.prod(x.shape)) * jax.dtypes.canonicalize_dtype(
                x.dtype
            ).itemsize
            for x in jax.tree.leaves(payload)
        )
    )


def _pattern(
    collective: str,
    length: int,
    payload_bytes: float,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
):
    """(bytes, messages) for one worker, one round."""
    sizes = [int(s) for s in dp_sizes] or [1]
    n = int(np.prod(sizes))
    if collective == "dense_allreduce":
        return 2.0 * (n - 1) / max(n, 1) * length * word_bytes, 2 * (n - 1)
    if collective == "sparse_allgather":
        return (n - 1) * payload_bytes, n - 1
    if collective == "hierarchical":
        # last dp axis = intra (fast, dense allreduce); outer axes = inter
        # (slow, compressed payload allgather) — matches Hierarchical.shard.
        a = sizes[-1]
        b = int(np.prod(sizes[:-1])) if len(sizes) > 1 else 1
        inter = (b - 1) * payload_bytes
        intra = 2.0 * (a - 1) / max(a, 1) * length * word_bytes
        return inter + intra, (b - 1) + 2 * (a - 1)
    raise ValueError(f"unknown collective {collective!r}")


def predicted_bytes(
    codec: Codec | str,
    collective: str,
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
) -> int:
    """Per-worker bytes/round from the codec's exact bit accounting.
    ``word_bytes`` sizes the dense terms (4 for fp32, 2 for bf16 state)."""
    c = get_codec(codec) if isinstance(codec, str) else codec
    pb = math.ceil(int(c.wire_bits(length, k)) / 8)
    by, _ = _pattern(collective, length, pb, dp_sizes, word_bytes)
    return math.ceil(by)


def measured_bytes(
    collective: str,
    length: int,
    payload: Payload,
    dp_sizes: Sequence[int],
    word_bytes: int = WORD_BYTES,
) -> int:
    """Per-worker bytes/round from the *actual* encoded buffers."""
    by, _ = _pattern(
        collective, length, payload_nbytes(payload), dp_sizes, word_bytes
    )
    return math.ceil(by)


def predict(
    codec: Codec | str,
    collective: str,
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    model: AlphaBeta = AlphaBeta(),
    word_bytes: int = WORD_BYTES,
) -> CostEstimate:
    c = get_codec(codec) if isinstance(codec, str) else codec
    pb = math.ceil(int(c.wire_bits(length, k)) / 8)
    by, msgs = _pattern(collective, length, pb, dp_sizes, word_bytes)
    return CostEstimate(
        bytes_on_wire=math.ceil(by),
        n_messages=msgs,
        seconds=msgs * model.alpha + by * model.beta,
    )


def wire_words_per_worker(
    mode: str, length: int, k: int, n_workers: int
) -> int:
    """Legacy analytic words/round (pre-``repro.comm`` interface).

    Kept for the comm_volume benchmark table; new code should use
    :func:`predict` which accounts for codec bit width and mesh shape.
    """
    if mode == "dense_allreduce":
        return length
    if mode == "sparse_allgather":
        return 2 * k * n_workers
    raise ValueError(f"unknown aggregation {mode!r}")
