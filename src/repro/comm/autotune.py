"""Cost-model-driven per-leaf (codec x collective) auto-planning.

The paper fixes one wire format for every layer; real parameter trees are
wildly heterogeneous — a 4-element bias shard and a 10^7-element embedding
shard at the same sparsity S want *different* codecs (``coo_idx_delta``'s
int8 deltas on tiny shards, ``bitmap_dense`` once S > 1/32) and different
collectives (``hierarchical`` only pays off when a multi-axis dp mesh has
slow outer links). This module picks, per leaf, the (codec, collective)
pair that minimizes the alpha–beta cost model's predicted round time:

    seconds = n_messages * alpha + bytes_on_wire * beta

computed by :func:`repro.comm.cost.predict` from the codec's exact
``wire_bits`` accounting and the collective's ring pattern. Selection is
deterministic: ties break on fewer bytes, then lexicographic (codec,
collective) names.

Entry points:

* :func:`choose_leaf` — one (length, k, dp_sizes) -> :class:`LeafDecision`.
* :func:`plan_tree`   — a ``LeafPlan`` pytree -> :class:`CommPlan` with
  per-leaf decisions plus round totals.

``DistConfig.codec="auto"`` / ``collective="auto"`` route through here (see
``repro.core.distributed.build_plan``); fixing one of the two restricts the
candidate set to that axis. Lossy codecs (``coo_q8``) are *excluded* by
default — auto-planning must not silently change numerics — and opt in via
``allow_lossy=True``.

``model`` accepts either a scalar :class:`AlphaBeta` (every link identical)
or a per-mesh-axis :class:`LinkTopo` (one link class per dp axis, outermost
first — e.g. slow inter-node NICs over fast intra-node ICI). The topology
is what makes ``hierarchical`` plannable at all: under any *uniform* model
with ``alpha == 0`` its byte cost sits exactly on the
``min(dense_allreduce, sparse_allgather)`` envelope and is never strictly
preferred (proof in ``docs/comm.md``); with a slow outer axis it wins
outright. Fit topologies from real collectives with
:func:`repro.comm.calibrate.calibrate_topo`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax

from repro.comm import (
    cost as cost_lib,
    fastpath as fastpath_lib,
    overlap as overlap_lib,
)
from repro.comm.codec import CODECS, get_codec
from repro.comm.collectives import COLLECTIVES, get_collective
from repro.comm.cost import (
    AlphaBeta,
    CostEstimate,
    LinkModel,
    LinkTopo,
    WORD_BYTES,
    as_topo,
)

# dense_allreduce moves the dense vector — the codec never hits the wire,
# so one canonical codec slot represents it in the candidate set.
DENSE_CANONICAL_CODEC = "coo_fp32"


@dataclasses.dataclass(frozen=True)
class LeafDecision:
    """The planner's pick for one leaf, with its predicted cost.

    ``fused`` is the select→encode fastpath flag
    (:mod:`repro.comm.fastpath`): whether this leaf's payload should be
    produced by the fused Pallas pipeline. Always False when planning
    with ``fastpath="off"`` (the default)."""

    codec: str
    collective: str
    cost: CostEstimate
    fused: bool = False


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Per-leaf decisions (a pytree mirroring the ``LeafPlan`` tree) plus
    per-worker round totals under the link model that produced them.
    ``model`` is the :class:`LinkTopo` the planner actually scored with
    (scalar :class:`AlphaBeta` inputs are normalized to a uniform topo).

    ``buckets`` / ``timeline`` carry the bucketed overlap schedule when
    the plan was built with ``overlap=`` (:mod:`repro.comm.overlap`):
    per-bucket leaf groups with their (codec, collective) wire decisions,
    and the predicted overlapped-timeline stamps whose ``seconds``
    reduces to ``total_seconds`` at one bucket and never exceeds it.
    ``total_seconds`` itself stays the synchronous per-leaf sum."""

    decisions: Any
    total_bytes: int
    total_messages: int
    total_seconds: float
    model: LinkTopo
    buckets: Optional[overlap_lib.BucketPlan] = None
    timeline: Optional[overlap_lib.Timeline] = None

    def flat(self):
        return jax.tree.leaves(
            self.decisions, is_leaf=lambda x: isinstance(x, LeafDecision)
        )


def candidate_pairs(
    codecs: Optional[Sequence[str]] = None,
    collectives: Optional[Sequence[str]] = None,
    allow_lossy: bool = False,
) -> Tuple[Tuple[str, str], ...]:
    """Admissible (codec, collective) pairs for one leaf.

    * ``dense_allreduce`` is codec-independent (nothing is encoded on the
      wire): it appears once, under the canonical fp32 codec slot — or the
      caller's single fixed codec when the codec axis is restricted, so a
      fixed-codec candidate set still contains the dense pattern.
    * lossy codecs are admissible only with ``allow_lossy=True`` (callers
      set it when the user *explicitly* fixed a lossy codec).
    * ``hierarchical`` degenerates to a dense psum on a single-axis dp mesh
      (no inter axes); it stays admissible but can never beat
      ``dense_allreduce`` there (identical pattern, later tie-break).

    >>> candidate_pairs(codecs=["bitmap_dense"],
    ...                 collectives=["sparse_allgather"])
    (('bitmap_dense', 'sparse_allgather'),)
    >>> any(c == "coo_q8" for c, _ in candidate_pairs())
    False
    """
    codec_axis_free = codecs is None
    cnames = sorted(CODECS) if codecs is None else list(codecs)
    snames = sorted(COLLECTIVES) if collectives is None else list(collectives)
    pairs = []
    for s in snames:
        get_collective(s)  # fail fast on unknown strategy
        if s == "dense_allreduce":
            dc = DENSE_CANONICAL_CODEC if codec_axis_free else cnames[0]
            get_codec(dc)  # fail fast on unknown codec
            pairs.append((dc, s))
            continue
        for c in cnames:
            codec = get_codec(c)  # fail fast on unknown codec
            if not codec.lossless and not allow_lossy:
                continue
            pairs.append((c, s))
    if not pairs:
        raise ValueError(
            "no admissible (codec, collective) pairs: codecs="
            f"{cnames} collectives={snames} allow_lossy={allow_lossy}"
        )
    return tuple(pairs)


def choose_leaf(
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    model: LinkModel = AlphaBeta(),
    *,
    codecs: Optional[Sequence[str]] = None,
    collectives: Optional[Sequence[str]] = None,
    allow_lossy: bool = False,
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
    fastpath: str = "off",
    compute: Optional[fastpath_lib.ThroughputTable] = None,
) -> LeafDecision:
    """Score every admissible pair with ``cost.predict``; return the argmin.

    Ordering is total and deterministic: (seconds, bytes, codec, collective).

    ``fastpath`` prices the *compute* stage (select→encode) alongside the
    wire cost and records the per-leaf ``fused`` flag: ``"off"`` (default)
    prices wire only and never fuses; ``"on"`` fuses every pair the
    fusability matrix admits; ``"auto"`` fuses where the
    measured-throughput ``compute`` table (default: the analytic
    HBM-traffic :class:`~repro.comm.fastpath.ThroughputTable`) says the
    fused pipeline is faster. With a non-"off" mode each candidate pair's
    seconds include its cheapest admissible compute path, so a fusable
    codec can out-plan a byte-cheaper one whose encode needs the dense
    intermediates (callers gate on ``config_fusable`` for the
    sparsifier-side rules — this function only sees wire and shape).

    ``model`` is a scalar :class:`AlphaBeta` or a per-axis
    :class:`LinkTopo` (length must equal ``len(dp_sizes)``).

    ``participants`` scores every candidate at a *partial* round (the
    expected on-time worker count of a straggler schedule — see
    ``Participation.expected_participants``), so auto-planning can trade
    dropout rate against wire cost.

    ``word_bytes`` sizes the ``dense_allreduce`` wire (the sparsified dense
    psum carries the state dtype — 2 for bf16). Payload strategies always
    decode to f32 before any intra-axis psum (see ``Hierarchical.shard``),
    so their dense terms stay at 4-byte words — the same split
    ``distributed.comm_round_bytes`` accounts with.

    A tiny shard rides delta-encoded COO indices; a slow outer axis flips a
    big, moderately sparse shard to ``hierarchical``:

    >>> choose_leaf(64, 2, (8,)).codec
    'coo_idx_delta'
    >>> slow_outer = LinkTopo((AlphaBeta(1e-5, 1e-10),
    ...                        AlphaBeta(1e-6, 1e-11)))
    >>> choose_leaf(10**6, 10**5, (2, 4), slow_outer).collective
    'hierarchical'
    """
    model = as_topo(model, max(len(list(dp_sizes)), 1))
    if fastpath not in fastpath_lib.FASTPATH_MODES:
        raise ValueError(
            f"unknown fastpath {fastpath!r}; "
            f"available: {fastpath_lib.FASTPATH_MODES}"
        )
    table = compute or fastpath_lib.ThroughputTable()
    best = None
    for cname, sname in candidate_pairs(codecs, collectives, allow_lossy):
        wb = word_bytes if sname == "dense_allreduce" else WORD_BYTES
        est = cost_lib.predict(
            cname, sname, length, k, dp_sizes, model, wb, participants
        )
        fused = fastpath_lib.leaf_fused(
            fastpath, cname, sname, length, k, table
        )
        seconds = est.seconds
        if fastpath != "off":
            seconds += table.seconds(length, k, fused)
        key = (seconds, est.bytes_on_wire, cname, sname)
        if best is None or key < best[0]:
            best = (key, LeafDecision(cname, sname, est, fused))
    return best[1]


def plan_tree(
    plan: Any,
    dp_sizes: Sequence[int],
    model: LinkModel = AlphaBeta(),
    *,
    codecs: Optional[Sequence[str]] = None,
    collectives: Optional[Sequence[str]] = None,
    allow_lossy: bool = False,
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
    fastpath: str = "off",
    compute: Optional[fastpath_lib.ThroughputTable] = None,
    overlap: Optional[overlap_lib.OverlapConfig] = None,
) -> CommPlan:
    """Plan every leaf of a ``LeafPlan`` pytree (``repro.core.distributed``).

    Each leaf is planned on its *local* shard length and k — the shapes the
    payload actually has inside ``shard_map``. ``model`` follows
    :func:`choose_leaf` (scalar :class:`AlphaBeta` or per-axis
    :class:`LinkTopo`); the returned :class:`CommPlan` carries the
    normalized topology.

    ``overlap`` additionally schedules the decided leaves into launch
    buckets (:func:`repro.comm.overlap.bucketize` over each leaf's
    per-axis stage seconds) and attaches the predicted overlapped
    :class:`~repro.comm.overlap.Timeline` — ``timeline.seconds`` never
    exceeds ``total_seconds`` and reduces to it at one bucket.

    >>> from jax.sharding import PartitionSpec as P
    >>> from repro.core.distributed import LeafPlan
    >>> tree = {"bias": LeafPlan((64,), (64,), 64, 4, P(None)),
    ...         "embed": LeafPlan((65536,), (65536,), 65536, 8192, P(None))}
    >>> cp = plan_tree(tree, (8,))
    >>> cp.decisions["bias"].codec, cp.decisions["embed"].codec
    ('coo_idx_delta', 'bitmap_dense')
    >>> cp.buckets is None
    True
    >>> cp2 = plan_tree(tree, (8,),
    ...                 overlap=overlap_lib.OverlapConfig(n_buckets=2))
    >>> cp2.buckets.n_buckets, sorted(cp2.buckets.leaf_order())
    (2, [0, 1])
    >>> cp2.timeline.seconds <= cp2.total_seconds + 1e-12
    True
    """
    from repro.core.distributed import LeafPlan  # cycle-free at call time

    model = as_topo(model, max(len(list(dp_sizes)), 1))

    def mk(p: LeafPlan) -> LeafDecision:
        return choose_leaf(
            p.local_len,
            p.k,
            dp_sizes,
            model,
            codecs=codecs,
            collectives=collectives,
            allow_lossy=allow_lossy,
            word_bytes=word_bytes,
            participants=participants,
            fastpath=fastpath,
            compute=compute,
        )

    decisions = jax.tree.map(
        mk, plan, is_leaf=lambda x: isinstance(x, LeafPlan)
    )
    flat = jax.tree.leaves(
        decisions, is_leaf=lambda x: isinstance(x, LeafDecision)
    )
    buckets = timeline = None
    if overlap is not None and flat:
        plan_leaves = jax.tree.leaves(
            plan, is_leaf=lambda x: isinstance(x, LeafPlan)
        )
        costs = [
            overlap_lib.leaf_cost(
                d.codec,
                d.collective,
                p.local_len,
                p.k,
                dp_sizes,
                model,
                word_bytes=(
                    word_bytes
                    if d.collective == "dense_allreduce"
                    else WORD_BYTES
                ),
                participants=participants,
            )
            for p, d in zip(plan_leaves, flat, strict=True)
        ]
        buckets = overlap_lib.bucketize(costs, overlap)
        timeline = overlap_lib.overlap_timeline(buckets)
    return CommPlan(
        decisions=decisions,
        total_bytes=sum(d.cost.bytes_on_wire for d in flat),
        total_messages=sum(d.cost.n_messages for d in flat),
        total_seconds=sum(d.cost.seconds for d in flat),
        model=model,
        buckets=buckets,
        timeline=timeline,
    )


def replan(
    plan: Any,
    dp_sizes: Sequence[int],
    samples: Sequence[Any],
    *,
    k_overrides: Any = None,
    codecs: Optional[Sequence[str]] = None,
    collectives: Optional[Sequence[str]] = None,
    allow_lossy: bool = False,
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
    fastpath: str = "off",
    compute: Optional[fastpath_lib.ThroughputTable] = None,
    overlap: Optional[overlap_lib.OverlapConfig] = None,
) -> CommPlan:
    """Re-plan every leaf from *measured* round samples, mid-training.

    The static cost model the first plan scored with is a prior; after a
    few rounds the ``calibrate`` machinery has real ``Sample`` rows
    (measured seconds against the ring pattern's message/byte counts —
    from :func:`repro.comm.calibrate.time_collective` on the live mesh,
    or assembled from the training loop's own round timings). ``replan``
    fits a fresh :class:`AlphaBeta` from those rows with
    :func:`repro.comm.calibrate.fit_alpha_beta` and re-runs
    :func:`plan_tree` under the fitted model, so the per-leaf
    (codec x collective) choices track what the wire actually does.

    ``k_overrides`` (optional) is a pytree of ints mirroring ``plan``:
    the adaptive controller's *current* per-leaf k, so replanning scores
    the wire at the k actually being sent rather than the static plan's.
    Only the scoring k changes — payload capacity and state shapes are
    the caller's concern (they stay at ``k_max``).

    >>> from repro.comm.calibrate import Sample
    >>> from jax.sharding import PartitionSpec as P
    >>> from repro.core.distributed import LeafPlan
    >>> tree = {"w": LeafPlan((4096,), (4096,), 4096, 41, P(None))}
    >>> rows = [Sample("probe", i, m, b, m * 1e-4 + b * 1e-9)
    ...         for i, (m, b) in enumerate([(7, 1000), (14, 100000),
    ...                                     (3, 5000000)])]
    >>> cp = replan(tree, (8,), rows)
    >>> cp.decisions["w"].codec  # alpha-heavy fit -> fewest messages win
    'coo_idx_delta'
    >>> cp.model.links[0].alpha >= 9e-5
    True
    """
    from repro.comm.calibrate import fit_alpha_beta
    from repro.core.distributed import LeafPlan  # cycle-free at call time

    fitted = fit_alpha_beta(list(samples))
    scored = plan
    if k_overrides is not None:
        scored = jax.tree.map(
            lambda p, kk: p._replace(k=int(kk)),
            plan,
            k_overrides,
            is_leaf=lambda x: isinstance(x, LeafPlan),
        )
    return plan_tree(
        scored,
        dp_sizes,
        fitted,
        codecs=codecs,
        collectives=collectives,
        allow_lossy=allow_lossy,
        word_bytes=word_bytes,
        participants=participants,
        fastpath=fastpath,
        compute=compute,
        overlap=overlap,
    )
