"""Fused select→encode fastpath: policy, pricing, and runtime routing.

The Pallas pipeline in ``repro.kernels.fused_encode`` produces the compact
``(idx, val)`` wire payload straight from the score-kernel registers — no
dense score write-back, no dense mask, no dense masked gradient, no
separate ``a[idx]`` gather. This module is everything *around* that
kernel:

* **fusability matrix** — :func:`fusable` and its factors
  (:func:`config_fusable` / :func:`wire_fusable` / :func:`shape_fusable`):
  which (sparsifier x selector x codec x collective x shape) combinations
  the fused pipeline reproduces bit-for-bit. Everything else stays on the
  unfused path; routing is always a per-leaf decision, never a global
  switch.
* **pricing** — :class:`ThroughputTable`, the measured-throughput table
  behind ``fastpath="auto"``: analytic HBM-traffic defaults
  (:func:`fused_hbm_bytes` / :func:`unfused_hbm_bytes`, the same columns
  ``benchmarks/kernel_bench.py`` reports) with a :meth:`ThroughputTable.measure`
  refit from real kernel timings. ``repro.comm.autotune.choose_leaf``
  prices each candidate (codec x collective) pair's compute stage with it
  and records the per-leaf ``fused`` flag on its :class:`LeafDecision`.
* **runtime routing** — :func:`fused_compact_select`, the drop-in
  replacement for ``repro.core.compact.compact_select`` on fusable
  configs. The kernel's exactness certificate gates a ``lax.cond``
  fallback to the dense path, so the routed result is bit-for-bit equal
  to the unfused one *unconditionally*; the certificate only decides
  which pipeline computed it.

``DistConfig.fastpath`` / ``DistributedSim(fastpath=...)`` / the train
CLI's ``--fastpath`` accept ``"off"`` (default, historical path),
``"on"`` (fuse every fusable leaf), and ``"auto"`` (fuse where the table
says the fused pipeline is faster; resolves to "off" off-TPU, where the
kernels run in interpret mode). See ``docs/comm.md#the-fused-fastpath``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.codec import Codec, get_codec

FASTPATH_MODES = ("off", "on", "auto")

# tanh(x) == 1.0 exactly in float32 for x >= ~8.7; with margin. Below this,
# the unsent-coordinate regularizer C = tanh((1 + Q)/mu) is < 1 and the
# fused score (which applies C explicitly) diverges from the unfused
# compact path (which leaves unsent scores untouched).
SATURATION_MIN = 12.0

# per-tile candidate budget bounds: the kernel unrolls m masked-max rounds,
# so m is capped; the floor keeps the certificate hit-rate high on tiny k.
MIN_M = 8
MAX_M = 128

_TILE = 8192  # repro.kernels layout contract: (8, 1024) f32 tiles


def _n_tiles(length: int) -> int:
    return max(1, -(-int(length) // _TILE))


def candidate_budget(length: int, k: int) -> int:
    """Per-tile candidate count ``m`` for a leaf: ~2.5x the expected
    per-tile winner count ``k / n_tiles`` plus slack, clamped to
    [MIN_M, MAX_M]. Oversampling keeps the exactness certificate's
    fast-path hit rate high on uniform-ish score mass.

    >>> candidate_budget(8192, 8)
    28
    >>> candidate_budget(10**6, 10)
    9
    """
    per_tile = k / _n_tiles(length)
    return max(MIN_M, min(MAX_M, math.ceil(2.5 * per_tile) + 8))


def config_fusable(scfg) -> Tuple[bool, str]:
    """Does this ``SparsifierConfig`` admit the fused pipeline?

    * kind must be ``topk``/``regtopk`` — the only kinds whose score the
      kernel computes (cyclic/coordtopk/dgc score from other state).
    * selector must be ``exact`` — the fused compaction reproduces
      ``lax.top_k`` ordering; the ``threshold`` selector's
      ``mask_to_payload`` ranks the payload by |value| instead.
    * ``y > 0`` — keeps the score chain well defined on zero magnitudes.
    * both kinds need ``tanh((1 + Q)/mu) == 1.0`` in f32
      (:data:`SATURATION_MIN`): the unfused path never scales unsent
      (topk: any) scores, the kernel multiplies them by that constant —
      and a constant *below* 1.0 can collapse 1-ulp-separated magnitudes
      into f32 ties, silently reordering the selection.

    Bit-for-bit subtlety the routing (not this predicate) handles: where
    the unfused path scores plain ``|a|`` (all of topk; regtopk's t == 0
    round) the kernel must not apply ``y != 1`` either — ``x^y`` is
    order-*preserving* but not tie-*preserving* in floats, so
    :func:`fused_compact_select` scores topk with ``y = 1`` and forces
    the dense fallback on regtopk's round 0 when ``y != 1``.
    """
    if scfg.kind not in ("topk", "regtopk"):
        return False, f"kind {scfg.kind!r} is not fusable"
    if scfg.selector != "exact":
        return False, f"selector {scfg.selector!r} is not fusable"
    if not scfg.y > 0:
        return False, f"y={scfg.y} breaks the score chain"
    if (1.0 + scfg.q_const) / scfg.mu < SATURATION_MIN:
        return False, (
            f"tanh((1+{scfg.q_const:g})/{scfg.mu:g}) does not saturate "
            "to 1.0 — scores would diverge from the unfused path"
        )
    return True, "ok"


def wire_fusable(codec, collective: str) -> Tuple[bool, str]:
    """Does this (codec, collective) pair consume the fused payload?

    * the codec must implement :meth:`Codec.encode_fused` — an epilogue
      over the k selected registers. ``bitmap_dense`` cannot: its wire
      format *is* a dense presence bitmap, the exact intermediate the
      fastpath never materializes.
    * the collective must move payloads; ``dense_allreduce`` scatters the
      dense vector regardless, so there is nothing to fuse into.

    >>> wire_fusable("coo_fp32", "sparse_allgather")[0]
    True
    >>> wire_fusable("bitmap_dense", "sparse_allgather")[0]
    False
    >>> wire_fusable("coo_q8", "dense_allreduce")[0]
    False
    """
    c = codec if isinstance(codec, Codec) else get_codec(codec)
    if not c.supports_fused:
        return False, f"codec {c.name!r} has no encode_fused epilogue"
    if collective == "dense_allreduce":
        return False, "dense_allreduce moves the dense vector, not payloads"
    return True, "ok"


def shape_fusable(length: int, k: int) -> Tuple[bool, str]:
    """Does the leaf shape fit the candidate budget? ``k`` must fit in
    ``n_tiles * m`` candidates with ``m <= MAX_M`` — at S = k/L beyond
    ~1.5% the per-tile budget overflows and selection stays unfused.

    >>> shape_fusable(65536, 64)[0]
    True
    >>> shape_fusable(8192, 1024)[0]
    False
    """
    m = candidate_budget(length, k)
    if k > _n_tiles(length) * m:
        return False, (
            f"k={k} exceeds the {_n_tiles(length)}x{m} candidate budget"
        )
    return True, "ok"


def fusable(
    scfg, codec, collective: str, length: int, k: int
) -> Tuple[bool, str]:
    """Full fusability matrix: config x wire x shape (see the factor
    functions for the individual rules)."""
    for ok, why in (
        config_fusable(scfg),
        wire_fusable(codec, collective),
        shape_fusable(length, k),
    ):
        if not ok:
            return False, why
    return True, "ok"


def backend_supports() -> bool:
    """Whether ``fastpath="auto"`` may fuse at all: off-TPU the Pallas
    kernels run in interpret mode, which is never faster than XLA's
    unfused path — "auto" resolves to "off" there ("on" still forces the
    fused path, e.g. for tests and parity benchmarks)."""
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# pricing: analytic HBM traffic + the measured-throughput table
# ---------------------------------------------------------------------------
def fused_hbm_bytes(length: int, k: int, m: Optional[int] = None) -> int:
    """Analytic HBM traffic of the fused pipeline: 4 J-sized f32 reads over
    the *padded* tiles plus the candidate triples and the k-payload write.
    The padding term is why tiny leaves price *worse* fused — one 8192
    tile dwarfs a 100-element leaf — and "auto" correctly leaves them
    unfused.

    >>> fused_hbm_bytes(65536, 64) < unfused_hbm_bytes(65536, 64)
    True
    >>> fused_hbm_bytes(100, 4) > unfused_hbm_bytes(100, 4)
    True
    """
    tiles = _n_tiles(length)
    m = candidate_budget(length, k) if m is None else m
    return 16 * tiles * _TILE + 12 * tiles * m + 8 * k


def unfused_hbm_bytes(length: int, k: int) -> int:
    """Analytic HBM traffic of the unfused chain: the score kernel's
    4 reads + 1 dense write, the selector's dense re-read, and the
    payload gather — 24 bytes/element + 8 bytes/coordinate."""
    return 24 * length + 8 * k


@dataclasses.dataclass(frozen=True)
class ThroughputTable:
    """Measured-throughput table pricing the select→encode compute stage.

    ``seconds(length, k, fused)`` divides the analytic HBM traffic by the
    per-path effective throughput. Defaults assume both paths stream at
    the same HBM rate (the kernel_bench roofline constant), under which
    the fused pipeline wins wherever its traffic is lower; refit from
    real kernel timings with :meth:`measure` — on CPU interpret mode that
    measurement correctly prices the fused path *slower* and "auto"
    declines it.
    """

    fused_bps: float = 819e9
    unfused_bps: float = 819e9

    def seconds(self, length: int, k: int, fused: bool) -> float:
        if fused:
            return fused_hbm_bytes(length, k) / self.fused_bps
        return unfused_hbm_bytes(length, k) / self.unfused_bps

    def prefers_fused(self, length: int, k: int) -> bool:
        return self.seconds(length, k, True) < self.seconds(length, k, False)

    @classmethod
    def measure(
        cls, length: int = 1 << 16, k: int = 64, iters: int = 3,
        interpret: Optional[bool] = None,
    ) -> "ThroughputTable":
        """Fit effective per-path throughput from real timings of the
        fused pipeline vs the unfused score→top_k→gather chain on a
        representative leaf."""
        import time

        from repro.kernels import ops

        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        a, a_prev, g_prev = (
            3.0 * jax.random.normal(kk, (length,)) for kk in ks[:3]
        )
        s_prev = (jax.random.uniform(ks[3], (length,)) > 0.5).astype(
            jnp.float32
        )

        def fused_fn(x):
            return ops.fused_select_encode(
                x, a_prev, s_prev, g_prev, k=k, omega=0.05, mu=1.0,
                interpret=interpret,
            )

        @jax.jit
        def unfused_fn(x):
            from repro.kernels import ref

            return ref.fused_select_encode_ref(
                x, a_prev, s_prev, g_prev, k, omega=0.05, mu=1.0
            )

        def med_seconds(fn):
            jax.block_until_ready(fn(a))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(a))
                ts.append(time.perf_counter() - t0)
            ts.sort()
            return max(ts[len(ts) // 2], 1e-9)

        return cls(
            fused_bps=fused_hbm_bytes(length, k) / med_seconds(fused_fn),
            unfused_bps=unfused_hbm_bytes(length, k) / med_seconds(unfused_fn),
        )


def leaf_fused(
    mode: str,
    codec,
    collective: str,
    length: int,
    k: int,
    table: Optional[ThroughputTable] = None,
    scfg=None,
) -> bool:
    """One leaf's fused flag under ``mode``: never for non-fusable wire or
    shape (or config, when ``scfg`` is given); always for ``"on"``;
    table-priced for ``"auto"``.

    >>> leaf_fused("on", "coo_fp32", "sparse_allgather", 65536, 64)
    True
    >>> leaf_fused("auto", "coo_fp32", "sparse_allgather", 100, 4)
    False
    >>> leaf_fused("on", "bitmap_dense", "sparse_allgather", 65536, 64)
    False
    """
    if mode not in FASTPATH_MODES:
        raise ValueError(
            f"unknown fastpath mode {mode!r}; available: {FASTPATH_MODES}"
        )
    if mode == "off":
        return False
    if scfg is not None and not config_fusable(scfg)[0]:
        return False
    if not (wire_fusable(codec, collective)[0] and shape_fusable(length, k)[0]):
        return False
    if mode == "on":
        return True
    return (table or ThroughputTable()).prefers_fused(length, k)


# ---------------------------------------------------------------------------
# runtime routing
# ---------------------------------------------------------------------------
def fused_compact_select(scfg, st, g, k: int, *, interpret=None):
    """Fused replacement for ``compact.compact_select`` on fusable configs.

    Returns the same ``(a, vals [k], idx [k])`` triple, bit-for-bit: the
    compact posterior statistics are scattered to the dense layout the
    kernel reads (state inputs, not the mask/masked-gradient
    intermediates the fusion eliminates), the pipeline emits the payload
    from score registers, and the exactness certificate ``lax.cond``s to
    the dense path whenever the candidate budget cannot prove the
    selection exact. Callers must have checked :func:`config_fusable`
    and :func:`shape_fusable`."""
    from repro.core import compact as C
    from repro.kernels import ops

    a = st.eps + g.astype(st.eps.dtype)
    L = a.shape[0]
    zeros = jnp.zeros((L,), a.dtype)
    y = scfg.y
    if scfg.kind == "regtopk":
        # t == 0 scatters an all-zero s_prev: every coordinate takes the
        # unsent branch and the score degrades to |a|^y — matching the
        # unfused plain-Top-k round 0 only when y == 1 (x^y preserves
        # order but can collapse 1-ulp-separated magnitudes into f32
        # ties); y != 1 forces the dense fallback on round 0 below.
        live = jnp.where(st.t > 0, 1.0, 0.0).astype(a.dtype)
        s_prev = zeros.at[st.sent_idx].set(live)
        a_prev = zeros.at[st.sent_idx].set(st.sent_vals)
        g_prev = zeros.at[st.sent_idx].set(st.sent_g)
    else:  # topk scores plain |a| whatever cfg.y says — so must we:
        # with s_prev all-zero and a saturated regularizer the kernel
        # score is exactly |a| * 1.0
        s_prev = a_prev = g_prev = zeros
        y = 1.0
    vals, idx, ok = ops.fused_select_encode(
        a, a_prev, s_prev, g_prev,
        k=k, omega=scfg.omega, mu=scfg.mu, q=scfg.q_const, y=y,
        m=candidate_budget(L, k), interpret=interpret,
    )
    if scfg.kind == "regtopk" and y != 1.0:
        ok = ok & (st.t > 0)
    vals = vals.astype(a.dtype)

    def _dense(_):
        _a, v, i = C.compact_select(scfg, st, g, k)
        return v.astype(a.dtype), i

    vals, idx = jax.lax.cond(ok, lambda _: (vals, idx), _dense, None)
    return a, vals, idx


def make_score_fn(interpret: Optional[bool] = None):
    """``SparsifierConfig.score_fn`` adapter: the fused Pallas score
    kernel in the dense-state simulator. The simulator's vmapped,
    dense-state step only fuses the *scoring* stage (4 reads + 1 write
    instead of ~9 streams); the full select→encode fusion needs the
    compact state layout and lives in the shard_map runtime."""
    from repro.kernels import ops

    def score_fn(a, a_prev, s_prev, g_prev, cfg):
        return ops.regtopk_score(
            a, a_prev, s_prev, g_prev,
            omega=cfg.omega, mu=cfg.mu, q=cfg.q_const, y=cfg.y,
            interpret=interpret,
        )

    return score_fn
