"""``repro.comm`` — the communication subsystem.

Five layers:

* :mod:`repro.comm.codec`       — wire codecs with exact bit accounting
  (``coo_fp32`` | ``coo_idx_delta`` | ``bitmap_dense`` | ``coo_q8``).
* :mod:`repro.comm.collectives` — aggregation strategies over payloads
  (``dense_allreduce`` | ``sparse_allgather`` | ``hierarchical``), each in
  single-process reference and in-``shard_map`` form.
* :mod:`repro.comm.cost`        — alpha–beta cost model (scalar
  :class:`AlphaBeta` or per-mesh-axis :class:`LinkTopo`) + measured
  bytes-on-wire counters surfaced in train-step metrics.
* :mod:`repro.comm.autotune`    — cost-model-driven per-leaf
  (codec x collective) planning behind ``codec="auto"``.
* :mod:`repro.comm.calibrate`   — micro-harness timing real collectives to
  fit :class:`AlphaBeta` (uniform) or a per-axis :class:`LinkTopo`
  (``calibrate_topo``).
* :mod:`repro.comm.participation` — partial-participation / staleness
  round schedules (:class:`Participation`) composing with every collective
  via renormalized per-round weights, priced by the cost model's
  ``participants=`` argument.
* :mod:`repro.comm.fastpath`     — the fused select→encode pipeline's
  policy layer: the fusability matrix, the measured-throughput
  :class:`ThroughputTable` behind ``fastpath="auto"``, and the runtime
  routing into the Pallas kernel (``repro.kernels.fused_encode``).
* :mod:`repro.comm.overlap`      — bucketed overlap scheduling: greedy
  size-balanced bucketing of the leaf tree (:func:`bucketize` →
  :class:`BucketPlan`) and the pipelined round :class:`Timeline` that
  hides the slow inter-axis stage behind the next bucket's intra-axis
  work, behind ``DistConfig.overlap="buckets:B"``.

See ``docs/comm.md`` for wire-format bit layouts, the collective ring
patterns, and the cost-model math (including why a uniform link model can
never strictly prefer ``hierarchical``).

All gradient aggregation in :mod:`repro.core.distributed` and
:mod:`repro.core.simulator` routes through this package, selected by
``DistConfig.codec`` / ``DistConfig.collective`` ("auto" plans per leaf).
"""
from repro.comm import autotune, calibrate, controller, fastpath, overlap
from repro.comm.autotune import (
    CommPlan,
    LeafDecision,
    choose_leaf,
    plan_tree,
    replan,
)
from repro.comm.calibrate import (
    Calibration,
    Sample,
    TopoCalibration,
    calibrate as run_calibration,
    calibrate_topo,
    fit_alpha_beta,
)
from repro.comm.codec import (
    CODECS,
    BitmapDense,
    Codec,
    CooFp32,
    CooIdxDelta,
    CooQ8,
    delta_index_dtype,
    get_codec,
)
from repro.comm.collectives import (
    COLLECTIVES,
    WEIGHTINGS,
    Collective,
    DenseAllreduce,
    Hierarchical,
    SparseAllgather,
    check_weighting,
    get_collective,
)
from repro.comm.controller import (
    AdaptiveKController,
    ControllerState,
    parse_adaptive_k,
    round_wire_bits,
)
from repro.comm.cost import (
    AlphaBeta,
    CostEstimate,
    LinkModel,
    LinkTopo,
    as_topo,
    measured_bytes,
    parse_link_topo,
    pattern_axes,
    payload_nbytes,
    predict,
    predicted_bytes,
    stage_seconds,
)
from repro.comm.fastpath import (
    FASTPATH_MODES,
    ThroughputTable,
    fusable,
    fused_compact_select,
)
from repro.comm.overlap import (
    Bucket,
    BucketPlan,
    LeafCost,
    OverlapConfig,
    Timeline,
    bucketize,
    leaf_cost,
    overlap_timeline,
    parse_overlap,
)
from repro.comm.participation import (
    PARTICIPATION_KINDS,
    Participation,
    parse_participation,
    renormalize_weights,
    worker_index,
)

__all__ = [
    "AdaptiveKController",
    "AlphaBeta",
    "BitmapDense",
    "Bucket",
    "BucketPlan",
    "CODECS",
    "COLLECTIVES",
    "Calibration",
    "Codec",
    "Collective",
    "CommPlan",
    "ControllerState",
    "CooFp32",
    "CooIdxDelta",
    "CooQ8",
    "CostEstimate",
    "DenseAllreduce",
    "FASTPATH_MODES",
    "Hierarchical",
    "LeafCost",
    "LeafDecision",
    "LinkModel",
    "LinkTopo",
    "OverlapConfig",
    "PARTICIPATION_KINDS",
    "Participation",
    "Sample",
    "SparseAllgather",
    "ThroughputTable",
    "Timeline",
    "TopoCalibration",
    "WEIGHTINGS",
    "as_topo",
    "autotune",
    "bucketize",
    "calibrate",
    "calibrate_topo",
    "check_weighting",
    "choose_leaf",
    "controller",
    "delta_index_dtype",
    "fastpath",
    "fit_alpha_beta",
    "fusable",
    "fused_compact_select",
    "get_codec",
    "get_collective",
    "leaf_cost",
    "measured_bytes",
    "overlap",
    "overlap_timeline",
    "parse_adaptive_k",
    "parse_link_topo",
    "parse_overlap",
    "parse_participation",
    "pattern_axes",
    "payload_nbytes",
    "plan_tree",
    "predict",
    "predicted_bytes",
    "renormalize_weights",
    "replan",
    "round_wire_bits",
    "run_calibration",
    "stage_seconds",
    "worker_index",
]
