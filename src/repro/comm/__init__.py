"""``repro.comm`` — the communication subsystem.

Three layers (ISSUE 1 tentpole):

* :mod:`repro.comm.codec`       — wire codecs with exact bit accounting
  (``coo_fp32`` | ``coo_idx_delta`` | ``bitmap_dense`` | ``coo_q8``).
* :mod:`repro.comm.collectives` — aggregation strategies over payloads
  (``dense_allreduce`` | ``sparse_allgather`` | ``hierarchical``), each in
  single-process reference and in-``shard_map`` form.
* :mod:`repro.comm.cost`        — alpha–beta cost model + measured
  bytes-on-wire counters surfaced in train-step metrics.

All gradient aggregation in :mod:`repro.core.distributed` and
:mod:`repro.core.simulator` routes through this package, selected by
``DistConfig.codec`` / ``DistConfig.collective``.
"""
from repro.comm.codec import (
    CODECS,
    BitmapDense,
    Codec,
    CooFp32,
    CooIdxDelta,
    CooQ8,
    delta_index_dtype,
    get_codec,
)
from repro.comm.collectives import (
    COLLECTIVES,
    Collective,
    DenseAllreduce,
    Hierarchical,
    SparseAllgather,
    get_collective,
)
from repro.comm.cost import (
    AlphaBeta,
    CostEstimate,
    measured_bytes,
    payload_nbytes,
    predict,
    predicted_bytes,
    wire_words_per_worker,
)

__all__ = [
    "AlphaBeta",
    "BitmapDense",
    "CODECS",
    "COLLECTIVES",
    "Codec",
    "Collective",
    "CooFp32",
    "CooIdxDelta",
    "CooQ8",
    "CostEstimate",
    "DenseAllreduce",
    "Hierarchical",
    "SparseAllgather",
    "delta_index_dtype",
    "get_codec",
    "get_collective",
    "measured_bytes",
    "payload_nbytes",
    "predict",
    "predicted_bytes",
    "wire_words_per_worker",
]
