"""Bucketed overlap scheduling: hide the wire behind compute.

Every round used to be strictly compute-then-communicate: the full leaf
tree's collectives run after the whole backward finishes, so the wire time
RegTop-k exists to shrink still sits entirely on the critical path. This
module splits the leaf tree into size-balanced *buckets* and schedules each
bucket's collective as soon as its slice of the backward is done, pipelining
``hierarchical``'s slow inter-axis payload allgather behind the intra-axis
work of the next bucket.

Three pieces, all deterministic and static (trace-time planning — nothing
here touches tracers):

* :func:`bucketize` — greedy LPT bin-pack of per-leaf predicted wire
  seconds (from :func:`repro.comm.cost.stage_seconds`, the per-axis
  decomposition of ``cost.pattern_axes``) into :class:`BucketPlan`, with a
  balance factor and optional min/max bucket byte bounds. LPT guarantees
  ``max bucket seconds <= 4/3 * max(total/B, max leaf seconds)``; tighter
  ``balance_factor`` values are honored by reducing the bucket count until
  the bound holds (one bucket always does).
* :func:`overlap_timeline` — the two-stage pipeline recurrence producing
  per-bucket launch / intra-done / complete stamps and the overlapped round
  ``seconds``. The intra stage (innermost dp axis: ``hierarchical``'s dense
  psum, or a flat collective on a single-axis mesh) and the inter stage
  (outer axes: the payload allgather) are modeled as two serial resources,
  so bucket ``i+1``'s intra stage runs while bucket ``i``'s inter stage is
  still on the slow wire. At ``n_buckets=1`` the timeline reduces exactly
  to today's synchronous sum, and it never exceeds it.
* :func:`parse_overlap` — the CLI/``DistConfig.overlap`` spec grammar
  (``"off" | "buckets:B"``).

The *numerics* are untouched by construction: bucketing only reorders the
per-leaf sparsify+aggregate calls inside the traced round (each leaf's math
is independent), so ``overlap="off"`` and any bucket count are bit-for-bit
identical — asserted across codecs in ``tests/test_overlap.py`` and on a
real 8-device mesh in ``tests/test_distributed.py``. What changes is the
*schedule* the planner predicts (``CommPlan.buckets`` /
``CommPlan.timeline``) and the profiler-visible structure of the round
(each bucket runs under a ``jax.named_scope`` annotation, surfaced as
``metrics["timeline"]`` stamps by ``make_train_step``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

from repro.comm import cost as cost_lib
from repro.comm.cost import WORD_BYTES, AlphaBeta, LinkModel

# numeric slack for the balance-bound check: pure fp-summation noise must
# not force a pointless bucket-count reduction.
_BALANCE_TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class OverlapConfig:
    """Bucketed-overlap planning knobs.

    ``n_buckets`` is the *requested* bucket count (clamped to the leaf
    count; :func:`bucketize` may merge below it to honor
    ``min_bucket_bytes`` or reduce it to honor ``balance_factor``).
    ``balance_factor`` bounds the load imbalance: every returned plan
    satisfies ``max bucket seconds <= balance_factor * max(total/B,
    max leaf seconds)`` — 4/3 is the classic LPT guarantee, so the
    default never forces a reduction. ``min_bucket_bytes`` merges
    too-small buckets (launch overhead amortization);
    ``max_bucket_bytes`` steers leaves away from over-full buckets
    (best effort — a single over-cap leaf still needs a home).
    """

    n_buckets: int = 1
    balance_factor: float = 4.0 / 3.0
    min_bucket_bytes: int = 0
    max_bucket_bytes: Optional[int] = None

    def __post_init__(self):
        if self.n_buckets < 1:
            raise ValueError(f"n_buckets must be >= 1, got {self.n_buckets}")
        if self.balance_factor < 1.0:
            raise ValueError(
                f"balance_factor must be >= 1.0, got {self.balance_factor}"
            )
        if self.min_bucket_bytes < 0:
            raise ValueError(
                f"min_bucket_bytes must be >= 0, got {self.min_bucket_bytes}"
            )
        if (
            self.max_bucket_bytes is not None
            and self.max_bucket_bytes < max(self.min_bucket_bytes, 1)
        ):
            raise ValueError(
                f"max_bucket_bytes={self.max_bucket_bytes} below "
                f"min_bucket_bytes={self.min_bucket_bytes} (or < 1)"
            )


def parse_overlap(spec: str) -> Optional[OverlapConfig]:
    """Parse a ``DistConfig.overlap`` / ``--overlap`` spec.

    Grammar: ``"off"`` (no bucketing — the historical synchronous round,
    bit-for-bit) or ``"buckets:B"`` with ``B >= 1``.

    >>> parse_overlap("off") is None
    True
    >>> parse_overlap("buckets:4").n_buckets
    4
    >>> parse_overlap("buckets:0")
    Traceback (most recent call last):
        ...
    ValueError: n_buckets must be >= 1, got 0
    >>> parse_overlap("stream")
    Traceback (most recent call last):
        ...
    ValueError: unknown overlap spec 'stream'; expected 'off' or 'buckets:B'
    """
    s = spec.strip()
    if s == "off":
        return None
    if s.startswith("buckets:"):
        body = s[len("buckets:"):]
        try:
            n = int(body)
        except ValueError:
            raise ValueError(
                f"overlap spec {spec!r}: bucket count {body!r} is not an int"
            ) from None
        return OverlapConfig(n_buckets=n)
    raise ValueError(
        f"unknown overlap spec {spec!r}; expected 'off' or 'buckets:B'"
    )


class LeafCost(NamedTuple):
    """One leaf's predicted wire cost, decomposed per dp mesh axis.

    ``axis_seconds`` follows the ``dp_sizes`` ordering (outermost/slowest
    first, innermost last) — the same per-axis attribution as
    :func:`repro.comm.cost.pattern_axes`. ``wire`` labels the (codec,
    collective) pair the seconds were priced under (informational; empty
    strings when the caller prices raw stage times)."""

    bytes_on_wire: int
    axis_seconds: Tuple[float, ...]
    wire: Tuple[str, str] = ("", "")

    @property
    def seconds(self) -> float:
        return float(sum(self.axis_seconds))


class Bucket(NamedTuple):
    """One scheduled bucket: the leaf indices it carries (ascending, into
    the flat plan order), its per-axis wire seconds (elementwise sums over
    its leaves), total predicted seconds/bytes, and the per-leaf (codec,
    collective) wire decisions riding in it."""

    leaves: Tuple[int, ...]
    seconds: float
    bytes_on_wire: int
    axis_seconds: Tuple[float, ...]
    wire: Tuple[Tuple[str, str], ...] = ()

    @property
    def intra_seconds(self) -> float:
        """Innermost-axis stage time (the fast dense psum / flat stage)."""
        return self.axis_seconds[-1] if self.axis_seconds else 0.0

    @property
    def inter_seconds(self) -> float:
        """Outer-axes stage time (the slow payload allgather)."""
        return float(sum(self.axis_seconds[:-1]))


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """An ordered bucket schedule over the flat leaf tree.

    Buckets are launched in order (bucket 0's backward slice finishes
    first); together they partition ``range(n_leaves)`` exactly — every
    leaf in exactly one bucket, asserted by the hypothesis properties in
    ``tests/test_overlap.py``."""

    buckets: Tuple[Bucket, ...]
    config: OverlapConfig

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return sum(len(b.leaves) for b in self.buckets)

    def leaf_order(self) -> Tuple[int, ...]:
        """Leaf indices in launch order (bucket by bucket)."""
        return tuple(i for b in self.buckets for i in b.leaves)


class Timeline(NamedTuple):
    """Predicted per-bucket stamps of one overlapped round (seconds from
    round start): collective ``launch`` (backward slice done, intra stage
    free), ``intra_done`` (intra-axis stage finished), ``complete`` (inter
    stage drained). ``seconds`` is the overlapped round time
    (``complete[-1]``); ``sync_seconds`` the synchronous sum the same
    stages would take back-to-back — ``seconds <= sync_seconds`` always,
    with equality at one bucket."""

    launch: Tuple[float, ...]
    intra_done: Tuple[float, ...]
    complete: Tuple[float, ...]
    seconds: float
    sync_seconds: float


def leaf_cost(
    codec,
    collective: str,
    length: int,
    k: int,
    dp_sizes: Sequence[int],
    model: LinkModel = AlphaBeta(),
    word_bytes: int = WORD_BYTES,
    participants: Optional[float] = None,
) -> LeafCost:
    """Price one leaf for bucketing: predicted bytes plus per-axis stage
    seconds under ``model`` — the :func:`bucketize` input.

    On a slow-outer topology a ``hierarchical`` leaf splits into a large
    outer-axis (inter) stage and a small innermost (intra) stage — the
    two-resource shape :func:`overlap_timeline` pipelines:

    >>> from repro.comm.cost import AlphaBeta, LinkTopo
    >>> topo = LinkTopo((AlphaBeta(1e-5, 1e-9), AlphaBeta(1e-6, 1e-10)))
    >>> lc = leaf_cost("coo_fp32", "hierarchical", 10**6, 10**5, (2, 4), topo)
    >>> len(lc.axis_seconds)
    2
    >>> lc.wire
    ('coo_fp32', 'hierarchical')
    >>> abs(lc.seconds - sum(lc.axis_seconds)) < 1e-15
    True
    """
    est = cost_lib.predict(
        codec, collective, length, k, dp_sizes, model, word_bytes,
        participants,
    )
    ax = cost_lib.stage_seconds(
        codec, collective, length, k, dp_sizes, model, word_bytes,
        participants,
    )
    cname = codec if isinstance(codec, str) else codec.name
    return LeafCost(est.bytes_on_wire, ax, (cname, collective))


def _lpt_assign(costs, order, n_buckets, max_bytes):
    """Longest-processing-time greedy: place each leaf (descending
    seconds) into the least-loaded bucket, preferring buckets whose byte
    total stays under ``max_bytes`` (an empty bucket always accepts)."""
    loads = [0.0] * n_buckets
    nbytes = [0] * n_buckets
    bins: list = [[] for _ in range(n_buckets)]
    for i in order:
        cand = sorted(range(n_buckets), key=lambda j: (loads[j], j))
        pick = cand[0]
        if max_bytes is not None:
            for j in cand:
                if not bins[j] or nbytes[j] + costs[i].bytes_on_wire <= max_bytes:
                    pick = j
                    break
        bins[pick].append(i)
        loads[pick] += costs[i].seconds
        nbytes[pick] += costs[i].bytes_on_wire
    return [b for b in bins if b]


def _merge_small(bins, costs, min_bytes):
    """Fold buckets under ``min_bytes`` into the least-loaded survivor."""
    if min_bytes <= 0:
        return bins
    bins = [list(b) for b in bins]
    while len(bins) > 1:
        sizes = [sum(costs[i].bytes_on_wire for i in b) for b in bins]
        small = min(range(len(bins)), key=lambda j: (sizes[j], j))
        if sizes[small] >= min_bytes:
            break
        loads = [sum(costs[i].seconds for i in b) for b in bins]
        other = min(
            (j for j in range(len(bins)) if j != small),
            key=lambda j: (loads[j], j),
        )
        bins[other].extend(bins[small])
        del bins[small]
    return bins


def bucketize(
    costs: Sequence[LeafCost], config: OverlapConfig = OverlapConfig()
) -> BucketPlan:
    """Greedy size-balanced bin-pack of the leaf tree into a bucket
    schedule.

    Deterministic LPT: leaves sorted by descending predicted seconds (ties
    by index) go to the least-loaded bucket, honoring
    ``config.max_bucket_bytes`` when possible; buckets under
    ``config.min_bucket_bytes`` are merged away; if the result violates
    ``config.balance_factor`` the bucket count is reduced until it holds
    (a single bucket trivially does). Returned buckets are ordered by
    their smallest leaf index — the launch order of the backward slices —
    and partition ``range(len(costs))`` exactly.

    >>> costs = [LeafCost(400, (3e-3,)), LeafCost(400, (3e-3,)),
    ...          LeafCost(200, (1e-3,)), LeafCost(200, (1e-3,))]
    >>> bp = bucketize(costs, OverlapConfig(n_buckets=2))
    >>> [b.leaves for b in bp.buckets]
    [(0, 2), (1, 3)]
    >>> sorted(bp.leaf_order())
    [0, 1, 2, 3]
    >>> bucketize(costs, OverlapConfig(n_buckets=2,
    ...                                min_bucket_bytes=10**6)).n_buckets
    1
    """
    costs = list(costs)
    if not costs:
        raise ValueError("bucketize needs at least one leaf cost")
    n_axes = len(costs[0].axis_seconds)
    if any(len(c.axis_seconds) != n_axes for c in costs):
        raise ValueError(
            "every LeafCost must decompose over the same dp axes"
        )
    order = sorted(range(len(costs)), key=lambda i: (-costs[i].seconds, i))
    total = sum(c.seconds for c in costs)
    max_leaf = max(c.seconds for c in costs)
    assign = [order]
    for nb in range(min(config.n_buckets, len(costs)), 0, -1):
        assign = _merge_small(
            _lpt_assign(costs, order, nb, config.max_bucket_bytes),
            costs,
            config.min_bucket_bytes,
        )
        loads = [sum(costs[i].seconds for i in b) for b in assign]
        ideal = max(total / len(assign), max_leaf)
        if (
            len(assign) == 1
            or max(loads) <= config.balance_factor * ideal + _BALANCE_TOL
        ):
            break
    buckets = []
    for b in sorted(assign, key=min):
        idxs = tuple(sorted(b))
        ax = tuple(
            sum(costs[i].axis_seconds[a] for i in idxs)
            for a in range(n_axes)
        )
        buckets.append(
            Bucket(
                leaves=idxs,
                seconds=float(sum(ax)),
                bytes_on_wire=sum(costs[i].bytes_on_wire for i in idxs),
                axis_seconds=ax,
                wire=tuple(costs[i].wire for i in idxs),
            )
        )
    return BucketPlan(buckets=tuple(buckets), config=config)


def overlap_timeline(
    plan: BucketPlan,
    compute_seconds: Optional[Sequence[float]] = None,
) -> Timeline:
    """Predicted timeline of one overlapped round.

    Two serial resources, pipelined across buckets: the *intra* stage
    (innermost dp axis — ``hierarchical``'s dense psum, or the whole
    collective on a single-axis mesh) and the *inter* stage (outer axes —
    the payload allgather on the slow wire). Bucket ``i`` launches once
    its backward slice is done (``compute_seconds[i]``, cumulative) *and*
    the intra stage is free; its inter stage then drains behind the next
    bucket's intra work:

        ``launch[i]     = max(compute_done[i], intra_done[i-1])``
        ``intra_done[i] = launch[i] + intra[i]``
        ``complete[i]   = max(intra_done[i], complete[i-1]) + inter[i]``

    ``seconds = complete[-1]``; ``sync_seconds`` is the synchronous sum of
    every stage back-to-back. By induction ``seconds <= sync_seconds``,
    with exact equality at one bucket (no ``compute_seconds``):

    >>> two = bucketize([LeafCost(100, (2e-3, 1e-3)),
    ...                  LeafCost(100, (2e-3, 1e-3))],
    ...                 OverlapConfig(n_buckets=2))
    >>> tl = overlap_timeline(two)
    >>> tl.seconds < tl.sync_seconds
    True
    >>> one = overlap_timeline(bucketize([LeafCost(100, (2e-3, 1e-3))]))
    >>> one.seconds == one.sync_seconds
    True
    """
    comp = (
        [0.0] * plan.n_buckets
        if compute_seconds is None
        else [float(c) for c in compute_seconds]
    )
    if len(comp) != plan.n_buckets:
        raise ValueError(
            f"compute_seconds has {len(comp)} entries for "
            f"{plan.n_buckets} buckets"
        )
    if any(c < 0 for c in comp):
        raise ValueError("compute_seconds must be non-negative")
    launch, intra_done, complete = [], [], []
    comp_done = 0.0
    intra_free = 0.0
    inter_free = 0.0
    for b, c in zip(plan.buckets, comp, strict=True):
        comp_done += c
        t_launch = max(comp_done, intra_free)
        t_intra = t_launch + b.intra_seconds
        intra_free = t_intra
        t_complete = max(t_intra, inter_free) + b.inter_seconds
        inter_free = t_complete
        launch.append(t_launch)
        intra_done.append(t_intra)
        complete.append(t_complete)
    sync = sum(comp) + sum(b.seconds for b in plan.buckets)
    return Timeline(
        launch=tuple(launch),
        intra_done=tuple(intra_done),
        complete=tuple(complete),
        seconds=complete[-1],
        sync_seconds=sync,
    )
