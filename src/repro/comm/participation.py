"""Partial-participation / staleness-aware round schedules (ISSUE 4 tentpole).

The paper's setting is fully synchronous: every round, all ``N`` workers'
sparsified gradients reach the server, which broadcasts the weighted sum.
Real data-parallel fleets are not: stragglers miss the aggregation
deadline, and asynchronous pipelines apply their payloads rounds late.
Because RegTop-k's posterior statistics condition on the *last broadcast
aggregate* (``g_agg_prev``), who actually participated in a round directly
interacts with the paper's central object — accumulated error — which is
what ``benchmarks/straggler_bench.py`` measures.

A :class:`Participation` is a deterministic per-round schedule over the
flat data-parallel worker group. It composes with *every* registered
collective through one rule — mask, then renormalize the aggregation
weights (:func:`renormalize_weights`, surfaced as
:meth:`Participation.participating_weights`) — rather than being baked
into any one strategy: ``Collective.reference`` accepts the per-round
``[N]`` mask and renormalizes internally, while ``Collective.shard``
takes the worker's own mask entry, every worker deriving the round's
weights locally from the shared schedule (see
:mod:`repro.comm.collectives`).

Schedules (``kind``):

* ``full``        — every worker, every round. Guaranteed bit-for-bit
  identical to the no-participation code paths (callers skip the
  participation logic entirely at trace time when :attr:`is_full`).
* ``bernoulli``   — each worker independently drops with probability
  ``drop_rate`` (PRNG seeded by ``(seed, round)``, so the schedule is
  common knowledge: every worker can compute the round's mask locally
  without extra communication). Worker ``round % N`` is always kept so a
  round can never lose *all* workers (the renormalization stays finite).
* ``round_robin`` — deterministic stragglers: ``n_stragglers`` consecutive
  workers, rotating by ``n_stragglers`` per round, miss each round. The
  worst-case-fair pattern (every worker is a straggler equally often).
* ``stale``       — bounded-staleness async on top of the ``round_robin``
  drop pattern: a straggler's payload is not lost but arrives
  ``staleness`` rounds late and is applied with weight
  ``discount * omega_n`` (*not* renormalized — the late payload is extra
  mass on top of that round's renormalized on-time aggregate). The
  undelivered-payload state lives with the aggregator (see
  ``DistributedSim`` in ``src/repro/core/simulator.py``); each payload is
  delivered exactly once, at most ``staleness`` rounds after it was
  produced.
* ``sampled``     — federated client sampling: exactly ``n_sampled`` of
  ``N`` workers per round, drawn by a common-knowledge PRNG (seeded by
  ``(seed, round)`` like ``bernoulli``, so every worker and the cost
  model can enumerate the round's cohort locally —
  :meth:`Participation.round_participants`). Unlike ``bernoulli``, where
  a dropped worker *computed* a gradient it could not send, an unsampled
  client is idle: it computes nothing and its sparsifier state is
  untouched — which is what lets the fleet-scale simulator gather/scatter
  only the ``S`` sampled rows per round instead of updating all ``N``.

Dropped workers (``bernoulli`` / ``round_robin``) keep their whole
accumulated gradient in the error accumulator ``eps`` — error feedback
covers non-participation exactly like it covers sparsification — and
their posterior statistics stay frozen at the last round they actually
sent, since the server never saw the skipped payload (the freeze is
kind-specific: ``Sparsifier.on_dropped`` owns the slot semantics, since
e.g. DGC keeps its momentum buffer where RegTop-k keeps ``a_prev``).
``stale`` workers did send (late), so their state advances normally.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

PARTICIPATION_KINDS = ("full", "bernoulli", "round_robin", "stale", "sampled")


@dataclasses.dataclass(frozen=True)
class Participation:
    """Deterministic per-round participation schedule over ``N`` workers.

    >>> Participation("full").is_full
    True
    >>> Participation("round_robin", n_stragglers=2).kind
    'round_robin'
    >>> Participation("sampled", n_sampled=32).kind
    'sampled'
    >>> Participation("bogus")
    Traceback (most recent call last):
        ...
    ValueError: unknown participation kind 'bogus'; available: \
['full', 'bernoulli', 'round_robin', 'stale', 'sampled']
    """

    kind: str = "full"
    drop_rate: float = 0.0  # bernoulli: per-worker drop probability
    n_stragglers: int = 1  # round_robin/stale: dropped per round
    staleness: int = 1  # stale: rounds until the late payload lands
    discount: float = 1.0  # stale: weight multiplier on late payloads
    n_sampled: int = 1  # sampled: clients drawn per round
    seed: int = 0  # bernoulli/sampled PRNG seed

    def __post_init__(self):
        if self.kind not in PARTICIPATION_KINDS:
            raise ValueError(
                f"unknown participation kind {self.kind!r}; available: "
                f"{list(PARTICIPATION_KINDS)}"
            )
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(
                f"drop_rate must be in [0, 1), got {self.drop_rate}"
            )
        if self.n_stragglers < 1:
            raise ValueError(
                f"n_stragglers must be >= 1, got {self.n_stragglers}"
            )
        if self.staleness < 1:
            raise ValueError(
                f"staleness must be >= 1, got {self.staleness}"
            )
        if self.discount < 0.0:
            raise ValueError(
                f"discount must be >= 0, got {self.discount}"
            )
        if self.n_sampled < 1:
            raise ValueError(
                f"n_sampled must be >= 1, got {self.n_sampled}"
            )

    # -- schedule queries ---------------------------------------------------
    @property
    def is_full(self) -> bool:
        """True when the schedule never drops anyone — callers use this to
        skip participation logic entirely at trace time, which is what
        makes ``Participation("full")`` bit-for-bit identical to the
        historical all-workers-every-round paths."""
        return self.kind == "full" or (
            self.kind == "bernoulli" and self.drop_rate == 0.0
        )

    @property
    def delays_payloads(self) -> bool:
        """True when dropped payloads are delivered late (``stale``) rather
        than kept in the worker's error accumulator."""
        return self.kind == "stale"

    def validate(self, n_workers: int) -> "Participation":
        """Check the schedule is realizable over ``n_workers`` (e.g. the
        round-robin straggler count must leave at least one participant).

        >>> Participation("round_robin", n_stragglers=4).validate(4)
        Traceback (most recent call last):
            ...
        ValueError: n_stragglers=4 would drop every one of the 4 workers
        >>> Participation("bernoulli", drop_rate=0.5).validate(1)
        Traceback (most recent call last):
            ...
        ValueError: a non-full participation schedule needs a dp group of \
at least 2 workers, got 1
        """
        if not self.is_full and n_workers < 2:
            raise ValueError(
                "a non-full participation schedule needs a dp group of "
                f"at least 2 workers, got {n_workers}"
            )
        if (
            self.kind in ("round_robin", "stale")
            and self.n_stragglers >= n_workers
        ):
            raise ValueError(
                f"n_stragglers={self.n_stragglers} would drop every one "
                f"of the {n_workers} workers"
            )
        if self.kind == "sampled" and self.n_sampled > n_workers:
            raise ValueError(
                f"n_sampled={self.n_sampled} exceeds the fleet size "
                f"{n_workers}"
            )
        return self

    def round_mask(self, round_idx, n_workers: int) -> jax.Array:
        """``{0,1}`` float mask ``[N]`` of the round's participants.

        Pure function of ``(schedule, round_idx)`` — common knowledge, so
        every worker (and the cost model) computes it without
        communication. ``round_idx`` may be a traced scalar (the schedule
        is jit/scan-friendly).

        >>> Participation("round_robin", n_stragglers=1).round_mask(0, 4).tolist()
        [0.0, 1.0, 1.0, 1.0]
        >>> Participation("round_robin", n_stragglers=1).round_mask(2, 4).tolist()
        [1.0, 1.0, 0.0, 1.0]
        """
        n = int(n_workers)
        if self.is_full:
            return jnp.ones((n,), jnp.float32)
        r = jnp.asarray(round_idx, jnp.int32)
        if self.kind == "bernoulli":
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed), r)
            keep = jax.random.bernoulli(key, 1.0 - self.drop_rate, (n,))
            # liveness: one rotating worker always participates, so the
            # renormalized weights are always well defined.
            keep = keep.at[jnp.mod(r, n)].set(True)
            return keep.astype(jnp.float32)
        if self.kind == "sampled":
            sidx = self.round_participants(r, n)
            return jnp.zeros((n,), jnp.float32).at[sidx].set(1.0)
        # round_robin / stale: n_stragglers consecutive workers rotate out
        ns = min(int(self.n_stragglers), n - 1)
        dropped = jnp.mod(r * ns + jnp.arange(ns), n)
        return jnp.ones((n,), jnp.float32).at[dropped].set(0.0)

    def round_participants(self, round_idx, n_workers: int) -> jax.Array:
        """``sampled`` only: the round's cohort as ``[S]`` sorted int32
        worker indices — a pure function of ``(schedule, round_idx)``, so
        the server, every client, and the fleet-scale simulator's
        gather/scatter path enumerate the same cohort without
        communication. The static shape ``S = n_sampled`` is what keeps
        per-round traffic O(S·J) inside one jit.

        >>> p = Participation("sampled", n_sampled=2, seed=0)
        >>> s0 = p.round_participants(0, 6)
        >>> s0.shape, s0.dtype
        ((2,), dtype('int32'))
        >>> bool((s0 == p.round_participants(0, 6)).all())  # common knowledge
        True
        >>> Participation("full").round_participants(0, 6)
        Traceback (most recent call last):
            ...
        ValueError: round_participants is defined for kind='sampled', \
got 'full'
        """
        if self.kind != "sampled":
            raise ValueError(
                "round_participants is defined for kind='sampled', "
                f"got {self.kind!r}"
            )
        n = int(n_workers)
        s = min(int(self.n_sampled), n)
        r = jnp.asarray(round_idx, jnp.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), r)
        perm = jax.random.permutation(key, n)
        # ascending order: aggregation order (and therefore float summation
        # order) is independent of the draw, matching round_mask's scatter.
        return jnp.sort(perm[:s]).astype(jnp.int32)

    def participating_weights(
        self, weights: jax.Array, round_idx
    ) -> jax.Array:
        """The round's effective aggregation weights — base ``omega_n``
        masked to the participants and renormalized to sum to one (zero on
        dropped workers). Reference-form aggregation (the simulator,
        ``Collective.reference``) consumes exactly this; the shard forms
        derive the same weights locally from :meth:`round_mask` (one
        common participant weight for the gathered stack, the worker's own
        mask entry to silence its payload).

        >>> import jax.numpy as jnp
        >>> p = Participation("round_robin", n_stragglers=1)
        >>> p.participating_weights(jnp.full((4,), 0.25), 0).tolist()
        [0.0, 0.3333333432674408, 0.3333333432674408, 0.3333333432674408]
        """
        w = jnp.asarray(weights)
        if self.is_full:
            return w
        mask = self.round_mask(round_idx, w.shape[0])
        return renormalize_weights(w, mask)

    def expected_participants(self, n_workers: int) -> float:
        """Expected number of on-time workers per round — what the cost
        model prices a partial round with (see ``participants=`` on
        :func:`repro.comm.cost.pattern_axes`). The resulting figures
        describe the *synchronous round's critical path*; under ``stale``
        the stragglers' payload bytes are delayed, not saved (the
        amortized wire volume is unchanged), so treat the partial byte
        figure as per-round, not as a bandwidth saving.

        >>> Participation("round_robin", n_stragglers=2).expected_participants(8)
        6.0
        >>> Participation("bernoulli", drop_rate=0.5).expected_participants(9)
        5.0
        >>> Participation("sampled", n_sampled=32).expected_participants(2000)
        32.0
        """
        n = int(n_workers)
        if self.is_full:
            return float(n)
        if self.kind == "bernoulli":
            # the rotating liveness worker always participates
            return 1.0 + (n - 1) * (1.0 - self.drop_rate)
        if self.kind == "sampled":
            return float(min(int(self.n_sampled), n))
        return float(n - min(int(self.n_stragglers), n - 1))

    def effective_omega(self, n_workers: int) -> float:
        """The scalar aggregation weight a worker's own contribution
        carries in the broadcast — what RegTop-k's Line-8 posterior must
        subtract as ``omega``.

        For dropping/sampling schedules a worker's payload, *when it
        lands*, lands with the renormalized weight ``1/E|P_t|`` (its
        posterior statistics freeze across skipped rounds, so the
        conditioning is always on a round it actually sent). Under
        ``stale`` every payload lands and state advances every round, so
        the right figure is the unconditional per-round expectation:
        on-time renormalized mass plus discounted late mass,

            (1 - ns/N) * 1/(N - ns)  +  (ns/N) * discount/N
          =  1/N + ns * discount / N**2.

        The seed-era code used ``1/(N - ns)`` here, which ignores the
        late deliveries entirely — wrong whenever ``discount > 0``.

        >>> Participation("round_robin", n_stragglers=2).effective_omega(8)
        0.16666666666666666
        >>> Participation("stale", n_stragglers=1, discount=0.5).effective_omega(4)
        0.28125
        >>> Participation("stale", n_stragglers=1, discount=0.0).effective_omega(4)
        0.25
        >>> Participation("full").effective_omega(4)
        0.25
        """
        n = int(n_workers)
        if self.kind == "stale":
            ns = min(int(self.n_stragglers), n - 1)
            return 1.0 / n + ns * self.discount / float(n) ** 2
        return 1.0 / self.expected_participants(n)


def renormalize_weights(weights: jax.Array, mask: jax.Array) -> jax.Array:
    """Mask + renormalize aggregation weights: ``w*m / sum(w*m)``.

    Conservation invariant (tested in ``tests/test_stragglers.py``): the
    result is zero on dropped workers and sums to one whenever at least
    one participant has positive base weight.

    The division floor is the *result dtype's* smallest normal — a
    hardcoded f32 tiny would be a no-op underflow guard for bf16 weights
    (bf16 tiny is the same 2**-126 but the sum is computed in bf16) and
    the wrong epsilon under x64.

    >>> import jax.numpy as jnp
    >>> renormalize_weights(jnp.array([0.25, 0.25, 0.25, 0.25]),
    ...                     jnp.array([1.0, 0.0, 1.0, 0.0])).tolist()
    [0.5, 0.0, 0.5, 0.0]
    >>> renormalize_weights(jnp.full((2,), 0.5, jnp.bfloat16),
    ...                     jnp.zeros((2,), jnp.bfloat16)).dtype
    dtype(bfloat16)
    """
    wm = jnp.asarray(weights) * jnp.asarray(mask)
    return wm / jnp.maximum(wm.sum(), jnp.finfo(wm.dtype).tiny)


def worker_index(
    dp_axes: Sequence[str], dp_sizes: Sequence[int]
) -> jax.Array:
    """This worker's flat index over the dp mesh axes (outermost first) —
    callable only inside ``shard_map``. Matches the worker ordering of the
    simulator's leading vmap axis and of :meth:`Participation.round_mask`.

    >>> wid = worker_index(("pod", "data"), (2, 4))  # doctest: +SKIP
    """
    wid = jnp.zeros((), jnp.int32)
    for ax, size in zip(dp_axes, dp_sizes, strict=True):
        wid = wid * int(size) + jax.lax.axis_index(ax)
    return wid


def parse_participation(spec: Optional[str]) -> Participation:
    """Parse the train CLI's ``--participation`` spec.

    Grammar: ``kind[:a[,b[,c]]]`` with positional parameters per kind —
    ``bernoulli:drop_rate[,seed]``, ``round_robin:n_stragglers``,
    ``stale:n_stragglers[,staleness[,discount]]``,
    ``sampled:n_sampled[,seed]``; bare ``full`` (or an empty/None spec)
    is full participation.

    >>> parse_participation("bernoulli:0.25").drop_rate
    0.25
    >>> parse_participation("stale:1,2,0.5")
    Participation(kind='stale', drop_rate=0.0, n_stragglers=1, staleness=2, \
discount=0.5, n_sampled=1, seed=0)
    >>> parse_participation("sampled:32,7")
    Participation(kind='sampled', drop_rate=0.0, n_stragglers=1, staleness=1, \
discount=1.0, n_sampled=32, seed=7)
    >>> parse_participation("full").is_full
    True
    """
    if not spec:
        return Participation("full")
    kind, _, rest = spec.strip().partition(":")
    kind = kind.strip()
    args = [a.strip() for a in rest.split(",") if a.strip()] if rest else []
    try:
        if kind == "full":
            if args:
                raise ValueError("'full' takes no parameters")
            return Participation("full")
        if kind == "bernoulli":
            if not 1 <= len(args) <= 2:
                raise ValueError("expected bernoulli:drop_rate[,seed]")
            return Participation(
                "bernoulli",
                drop_rate=float(args[0]),
                seed=int(args[1]) if len(args) > 1 else 0,
            )
        if kind == "round_robin":
            if len(args) != 1:
                raise ValueError("expected round_robin:n_stragglers")
            return Participation("round_robin", n_stragglers=int(args[0]))
        if kind == "stale":
            if not 1 <= len(args) <= 3:
                raise ValueError(
                    "expected stale:n_stragglers[,staleness[,discount]]"
                )
            return Participation(
                "stale",
                n_stragglers=int(args[0]),
                staleness=int(args[1]) if len(args) > 1 else 1,
                discount=float(args[2]) if len(args) > 2 else 1.0,
            )
        if kind == "sampled":
            if not 1 <= len(args) <= 2:
                raise ValueError("expected sampled:n_sampled[,seed]")
            return Participation(
                "sampled",
                n_sampled=int(args[0]),
                seed=int(args[1]) if len(args) > 1 else 0,
            )
    except ValueError as e:
        raise ValueError(f"bad --participation spec {spec!r}: {e}") from None
    raise ValueError(
        f"bad --participation spec {spec!r}: unknown kind {kind!r}; "
        f"available: {list(PARTICIPATION_KINDS)}"
    )
