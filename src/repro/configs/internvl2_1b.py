"""internvl2-1b [vlm]: InternViT (stub) + InternLM2-ish decoder.

24L, d_model=896, 14H (kv=2), d_ff=4864, vocab=151655; patch-embedding
prefix provided by the vision-frontend stub. [arXiv:2404.16821]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    n_patches=256,
    vision_dim=1024,
    rope_base=1e6,
    source="arXiv:2404.16821",
)
