"""granite-3-8b-swa [dense, beyond-assignment]: sliding-window variant.

Same dims as granite-3-8b with a 4096 sliding window — demonstrates the
dense->SWA escape hatch that makes long_500k decode feasible (DESIGN.md
§Shape-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b-swa",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab=49155,
    sliding_window=4096,
    source="hf:ibm-granite/granite-3.0-2b-base (+SWA, ours)",
)
