"""deepseek-moe-16b [moe]: fine-grained 64 routed top-6 + 2 shared experts.

28L, d_model=2048, 16H (kv=16), expert d_ff=1408, vocab=102400.
[arXiv:2401.06066]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    moe_top_k=6,
    n_shared_experts=2,
    moe_shared_d_ff=2816,  # 2 shared experts x 1408
    source="arXiv:2401.06066",
)
