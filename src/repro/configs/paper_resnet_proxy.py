"""Paper-native NN experiment proxy (ResNet-18/CIFAR-10 stand-in).

The paper trains ResNet-18 (11M params) on CIFAR-10 with 8 workers.
Offline container -> a compact transformer classifier on synthetic data
with a comparable parameter count exercises the same sparsified-DP path.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-resnet-proxy",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    head_dim=32,
    d_ff=1024,
    vocab=1024,
    remat=False,
    source="paper Sec. 5.2 (ResNet-18/CIFAR-10), proxied",
)
