"""Assigned architecture configs (public-literature pool) + input shapes.

Every config cites its source; exact dims per the assignment table.
Select with ``--arch <id>``; ``ARCHS[id]()`` returns the full ModelConfig,
``ARCHS[id]().smoke_variant()`` the reduced CPU-test variant.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

from repro.models.config import ModelConfig

_ARCH_MODULES = [
    "whisper_tiny",
    "qwen2_5_3b",
    "internvl2_1b",
    "mamba2_780m",
    "chatglm3_6b",
    "zamba2_7b",
    "mixtral_8x7b",
    "deepseek_moe_16b",
    "granite_3_8b",
    "phi3_medium_14b",
    "granite_3_8b_swa",  # beyond-assignment: SWA variant (long_500k escape hatch)
    "paper_resnet_proxy",  # the paper's own NN experiment proxy
]

ARCHS: Dict[str, Callable[[], ModelConfig]] = {}
for _m in _ARCH_MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    ARCHS[mod.CONFIG.name] = (lambda c: (lambda: c))(mod.CONFIG)


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]()
    except KeyError:
        raise ValueError(
            f"unknown arch {arch!r}; available: {sorted(ARCHS)}"
        ) from None


# --- assigned input shapes: (seq_len, global_batch, kind) ------------------
INPUT_SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# archs able to run long_500k (sub-quadratic / bounded-memory decode);
# see DESIGN.md §Shape-applicability for the skip rationale.
LONG_CONTEXT_OK = {
    "mamba2-780m",
    "zamba2-7b",
    "mixtral-8x7b",
    "granite-3-8b-swa",
}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
