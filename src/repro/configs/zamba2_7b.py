"""zamba2-7b [hybrid]: Mamba2 blocks + one SHARED attention block.

81 blocks, d_model=3584, shared attn 32H (kv=32, full MHA) d_ff=14336,
vocab=32000, ssm_state=64. Shared block applied every 6th position
(13 applications + 3 trailing mamba). [arXiv:2411.15242]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    attn_every=6,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    source="arXiv:2411.15242",
)
