"""whisper-tiny [audio]: enc-dec backbone, conv frontend stubbed.

4L (enc) + 4L (dec), d_model=384, 6H (kv=6), d_ff=1536, vocab=51865.
[arXiv:2212.04356]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    source="arXiv:2212.04356",
)
