"""Per-coordinate aggregation weights + S-of-N client sampling (ISSUE 9).

Covers the coordinate-weighting reduction (conservation over actual
senders, cross-strategy agreement, the worker-mode off-switch), the
``sampled`` participation schedule, the effective-omega fixes (stale
late mass, dtype-derived renormalization floor), and the kind-specific
dropped-worker delivery semantics (DGC momentum, CoordTopK staleness)
against an independent python delivery model — the mirror tests fail on
the pre-hook simulator rewrite, which is re-created here by forcing the
base-class ``on_dropped`` onto the kind.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.core.sparsify import CoordTopK, DGC, Sparsifier, make_sparsifier

jax.config.update("jax_platform_name", "cpu")

CODEC_NAMES = ("coo_fp32", "coo_q8")
STRATEGIES = ("dense_allreduce", "sparse_allgather", "hierarchical")


def _payload_case(W, L, k, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), W)
    vals, idxs = [], []
    for kk in ks:
        kv, ki = jax.random.split(kk)
        idx = jnp.sort(jax.random.permutation(ki, L)[:k])
        sign = jnp.sign(jax.random.normal(kv, (k,)))
        mag = 0.5 + jax.random.uniform(kv, (k,))
        vals.append(jnp.where(sign == 0, 1.0, sign) * mag)
        idxs.append(idx)
    return jnp.stack(vals), jnp.stack(idxs).astype(jnp.int32)


# ---------------------------------------------------------------------------
# the coordinate reduction: conservation + agreement + off-switch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cname", CODEC_NAMES)
def test_coordinate_weights_conserve_mass(cname):
    """The effective per-worker weight at coordinate j is w_n / den_j —
    summed over the workers that actually sent j it is exactly one, for
    any (non-uniform) base weights and any codec (presence is read off
    the *decoded* values, so lossy codecs conserve too)."""
    W, L, k = 5, 96, 9
    codec = comm.get_codec(cname)
    vals, idx = _payload_case(W, L, k)
    payloads = jax.vmap(lambda v, i: codec.encode(v, i, L))(vals, idx)
    w = jnp.asarray([0.4, 0.1, 0.2, 0.15, 0.15])
    agg, den = comm.get_collective("sparse_allgather").reference_coord(
        codec, payloads, w, L
    )
    dv, di = jax.vmap(lambda p: codec.decode(p, L))(payloads)
    presence = np.zeros((W, L))
    for n in range(W):
        for v, j in zip(np.asarray(dv[n]), np.asarray(di[n])):
            if v != 0:
                presence[n, j] = 1.0
    den_np = np.asarray(den)
    sent = presence.sum(axis=0) > 0
    eff = (np.asarray(w)[:, None] * presence) / np.where(
        den_np > 0, den_np, 1.0
    )
    np.testing.assert_allclose(eff.sum(axis=0)[sent], 1.0, rtol=1e-6)
    assert (den_np[~sent] == 0).all()
    assert np.asarray(jnp.isfinite(agg)).all()
    # uniform weights: den is the sender count over the round mass
    _, den_u = comm.get_collective("sparse_allgather").reference_coord(
        codec, payloads, jnp.full((W,), 1.0 / W), L
    )
    np.testing.assert_allclose(
        np.asarray(den_u), presence.sum(axis=0) / W, rtol=1e-6
    )


@pytest.mark.parametrize("cname", CODEC_NAMES)
def test_reference_coord_agrees_across_strategies(cname):
    W, L, k = 6, 64, 7
    codec = comm.get_codec(cname)
    vals, idx = _payload_case(W, L, k, seed=1)
    payloads = jax.vmap(lambda v, i: codec.encode(v, i, L))(vals, idx)
    w = jnp.full((W,), 1.0 / W)
    outs = {
        s: comm.get_collective(s).reference_coord(codec, payloads, w, L)
        for s in STRATEGIES
    }
    base_agg, base_den = outs["sparse_allgather"]
    # hierarchical's reference form is the identical flat reduction
    assert (outs["hierarchical"][0] == base_agg).all()
    assert (outs["hierarchical"][1] == base_den).all()
    np.testing.assert_allclose(
        np.asarray(outs["dense_allreduce"][0]), np.asarray(base_agg),
        rtol=1e-6, atol=1e-7,
    )
    np.testing.assert_allclose(
        np.asarray(outs["dense_allreduce"][1]), np.asarray(base_den),
        rtol=1e-6, atol=1e-7,
    )


@pytest.mark.parametrize("cname", CODEC_NAMES)
@pytest.mark.parametrize("sname", STRATEGIES)
def test_shard_coord_matches_reference_single_device(cname, sname):
    """shard_coord == reference_coord on an in-process 1-device mesh
    (the 8-device subprocess bit-for-bit check lives in
    tests/test_distributed.py)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map

    L, k = 96, 8
    codec = comm.get_codec(cname)
    strategy = comm.get_collective(sname)
    vals, idx = _payload_case(1, L, k, seed=2)
    payload = codec.encode(vals[0], idx[0], L)
    stacked = jax.tree.map(lambda x: x[None], payload)
    ref_agg, ref_den = strategy.reference_coord(
        codec, stacked, jnp.ones((1,)), L
    )
    mesh = make_mesh((1,), ("data",))
    in_specs = jax.tree.map(
        lambda x: P(*(("data",) + (None,) * x.ndim)), payload
    )

    def body(p):
        local = jax.tree.map(lambda x: x[0], p)
        return strategy.shard_coord(codec, local, L, ("data",), 1.0)

    with mesh:
        got_agg, got_den = shard_map(
            body, mesh=mesh, in_specs=(in_specs,),
            out_specs=(P(None), P(None)), check_vma=False,
        )(stacked)
    np.testing.assert_allclose(
        np.asarray(got_agg), np.asarray(ref_agg), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(got_den), np.asarray(ref_den), rtol=1e-6, atol=1e-7
    )


def test_worker_mode_omega_prev_ones_is_identity():
    """The off-switch argument: under worker weighting the threaded
    denominator is exactly 1.0, and dividing omega by 1.0 is the
    identity in floats — step(omega_prev=ones) is bit-for-bit
    step(omega_prev=None)."""
    J = 64
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.1, mu=1.0, omega=0.25)
    sp = make_sparsifier(cfg)
    st = sp.init(J)
    g0 = jax.random.normal(jax.random.PRNGKey(0), (J,))
    _, _, st = sp.step(st, g0, jnp.zeros(J))  # past round 0 (plain top-k)
    g1 = jax.random.normal(jax.random.PRNGKey(1), (J,))
    gp = jax.random.normal(jax.random.PRNGKey(2), (J,)) * 0.1
    ghat_a, mask_a, st_a = sp.step(st, g1, gp)
    ghat_b, mask_b, st_b = sp.step(st, g1, gp, omega_prev=jnp.ones(J))
    assert (ghat_a == ghat_b).all() and (mask_a == mask_b).all()
    for x, y in zip(st_a, st_b):
        assert (x == y).all()


def test_coordinate_weighting_changes_the_aggregate():
    codec = comm.get_codec("coo_fp32")
    W, L, k = 4, 32, 4
    vals, idx = _payload_case(W, L, k, seed=3)
    payloads = jax.vmap(lambda v, i: codec.encode(v, i, L))(vals, idx)
    w = jnp.full((W,), 1.0 / W)
    strat = comm.get_collective("sparse_allgather")
    worker = strat.reference(codec, payloads, w, L)
    coord, den = strat.reference_coord(codec, payloads, w, L)
    # masks are (generically) not identical, so some coordinate has
    # den < 1 and coordinate weighting rescales it
    assert float(jnp.abs(coord - worker).max()) > 0
    # at every sent coordinate: coord = worker / den (same numerator)
    sent = np.asarray(den) > 0
    np.testing.assert_allclose(
        np.asarray(coord)[sent],
        np.asarray(worker)[sent] / np.asarray(den)[sent],
        rtol=1e-6,
    )


def test_simulator_threads_den_into_posterior():
    """Coordinate mode: SimState.w_agg_prev after a round is the den the
    server divided by, and the invalid pairings fast-fail."""
    N, J = 4, 32
    b = jax.random.normal(jax.random.PRNGKey(0), (N, J))
    grad_fn = lambda th, n: th - b[n]
    sim = DistributedSim(
        grad_fn, N, J, SparsifierConfig(kind="regtopk", sparsity=0.2),
        collective="sparse_allgather", weighting="coordinate",
    )
    state = sim.init(jnp.zeros(J))
    assert state.w_agg_prev is not None and (state.w_agg_prev == 1.0).all()
    state, _ = jax.jit(lambda s: sim.step_fn(s))(state)
    den = np.asarray(state.w_agg_prev)
    assert ((den >= 0) & (den <= 1.0 + 1e-6)).all()
    assert (den > 0).any() and (den < 1.0).any()  # partial sender sets
    # den is a multiple of 1/N (uniform weights: sender_count / N)
    np.testing.assert_allclose(den * N, np.round(den * N), atol=1e-5)
    with pytest.raises(ValueError, match="weighting"):
        DistributedSim(
            grad_fn, N, J, SparsifierConfig(kind="none"),
            weighting="coordinate",
        )
    with pytest.raises(ValueError, match="stale"):
        DistributedSim(
            grad_fn, N, J, SparsifierConfig(kind="regtopk", sparsity=0.2),
            weighting="coordinate",
            participation=comm.Participation(
                "stale", n_stragglers=1, staleness=2
            ),
        )


# ---------------------------------------------------------------------------
# the sampled schedule
# ---------------------------------------------------------------------------
def test_round_participants_common_knowledge():
    p = comm.Participation(kind="sampled", n_sampled=4, seed=3)
    seen = set()
    for r in range(6):
        w = np.asarray(p.round_participants(r, 10))
        assert w.shape == (4,) and w.dtype == np.int32
        assert (np.diff(w) > 0).all()  # sorted, no repeats
        assert w.min() >= 0 and w.max() < 10
        np.testing.assert_array_equal(
            w, np.asarray(p.round_participants(r, 10))
        )
        seen.add(tuple(w.tolist()))
    assert len(seen) > 1  # fresh subset per round
    assert p.expected_participants(10) == 4.0
    assert p.effective_omega(10) == pytest.approx(0.25)
    with pytest.raises(ValueError, match="sampled"):
        comm.Participation(
            "round_robin", n_stragglers=1
        ).round_participants(0, 10)


def test_sampled_parse_and_validate():
    p = comm.parse_participation("sampled:32,7")
    assert p.kind == "sampled" and p.n_sampled == 32 and p.seed == 7
    with pytest.raises(ValueError):
        p.validate(8)  # S > N
    comm.parse_participation("sampled:4").validate(8)


def test_effective_omega_values():
    """Regression (PR-4 omega bug): under ``stale`` a worker's expected
    accepted mass is the on-time renormalized 1/N *plus* the discounted
    late deliveries — n_s rounds out of N it lands late at discount/N."""
    N = 8
    assert comm.Participation("full").effective_omega(N) == pytest.approx(
        1 / N
    )
    assert comm.Participation(
        "sampled", n_sampled=2
    ).effective_omega(N) == pytest.approx(0.5)
    bern = comm.Participation("bernoulli", drop_rate=0.25)
    assert bern.effective_omega(N) == pytest.approx(
        1.0 / bern.expected_participants(N)
    )
    st = comm.Participation(
        "stale", n_stragglers=2, staleness=2, discount=0.5
    )
    assert st.effective_omega(N) == pytest.approx(
        1.0 / N + 2 * 0.5 / N**2
    )


# ---------------------------------------------------------------------------
# renormalize_weights dtype floor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
def test_renormalize_weights_preserves_dtype(dtype):
    """Regression: the zero-mass floor was hardcoded
    ``finfo(float32).tiny`` — a non-weak f32 scalar that promoted the
    half-precision weight vectors to f32 on the way through."""
    dt = jnp.dtype(dtype)
    w = jnp.asarray([0.5, 0.125, 0.25, 0.125], dt)
    out = comm.renormalize_weights(w, jnp.asarray([1, 0, 1, 1], dt))
    assert out.dtype == dt
    np.testing.assert_allclose(
        float(out.astype(jnp.float32).sum()), 1.0, rtol=1e-2
    )
    zero = comm.renormalize_weights(w, jnp.zeros((4,), dt))
    assert zero.dtype == dt
    assert np.isfinite(np.asarray(zero.astype(jnp.float32))).all()


# ---------------------------------------------------------------------------
# kind-specific dropped-worker delivery vs an independent python model
# ---------------------------------------------------------------------------
def _topk_mask_np(score, k):
    k = min(int(k), score.shape[0])
    if k <= 0:
        return np.zeros_like(score)
    idx = np.argsort(-score, kind="stable")[:k]
    m = np.zeros_like(score)
    m[idx] = 1.0
    return m * (score > 0)


def _mirror_run(kind, part, b, steps, lr, k, momentum):
    """Round-by-round python delivery model: each worker runs its kind's
    local recursion; a dropped worker's send is simply lost — eps keeps
    the whole pre-send accumulator, while DGC's velocity and CoordTopK's
    common staleness counters advance exactly as the recursion says."""
    N, J = b.shape
    theta = np.zeros(J, np.float64)
    eps = np.zeros((N, J))
    slot = np.zeros((N, J))  # u for dgc; staleness counter for coordtopk
    g_prev = np.zeros(J)
    out = []
    for r in range(steps):
        m = np.asarray(part.round_mask(r, N), np.float64)
        w = m * (1.0 / N)
        w = w / w.sum()
        g_agg = np.zeros(J)
        for n in range(N):
            g = theta - b[n]
            if kind == "dgc":
                u = momentum * slot[n] + g
                v = eps[n] + u
                mask = _topk_mask_np(np.abs(v), k)
                ghat = mask * v
                slot[n] = (1.0 - mask) * u
                eps[n] = (v - ghat) if m[n] > 0 else v
            else:  # coordtopk
                a = eps[n] + g
                gmag = np.abs(g_prev)
                gn = gmag / max(gmag.max(), 1e-30)
                mask = _topk_mask_np(slot[n] + gn, k)
                ghat = mask * a
                slot[n] = np.where(mask > 0, 0.0, slot[n] + 1.0)
                eps[n] = (a - ghat) if m[n] > 0 else a
            if m[n] > 0:
                g_agg = g_agg + w[n] * ghat
        theta = theta - lr * g_agg
        g_prev = g_agg
        out.append(theta.copy())
    return np.stack(out)


def _sim_thetas(kind, part, b, steps, lr, sparsity, momentum):
    N, J = b.shape
    bj = jnp.asarray(b, jnp.float32)
    sim = DistributedSim(
        lambda th, n: th - bj[n], N, J,
        SparsifierConfig(kind=kind, sparsity=sparsity, momentum=momentum),
        learning_rate=lr, collective="dense_allreduce",
        participation=part,
    )
    _, tr = sim.run(jnp.zeros(J), steps, trace_fn=lambda th: th)
    return np.asarray(tr)


@pytest.mark.parametrize("kind", ["dgc", "coordtopk"])
@pytest.mark.parametrize(
    "schedule",
    [
        comm.Participation("bernoulli", drop_rate=0.4, seed=5),
        comm.Participation("round_robin", n_stragglers=1),
    ],
    ids=["bernoulli", "round_robin"],
)
def test_dropped_state_semantics_match_python_model(kind, schedule):
    """Regression (the ISSUE-9 bugfix): the simulator's dropped-worker
    rewrite assumed RegTop-k's slot layout — freezing DGC's momentum
    (re-applying velocity already folded into v) and CoordTopK's common
    staleness counters (desynchronizing the fleet's mask agreement).
    The kind-dispatched ``on_dropped`` must track the independent python
    delivery model; the pre-fix rewrite (re-created via the base-class
    hook below) must not."""
    N, J, steps, lr, k, mom = 3, 16, 8, 0.3, 3, 0.9
    rng = np.random.default_rng(0)
    b = rng.normal(size=(N, J))
    want = _mirror_run(kind, schedule, b, steps, lr, k, mom)
    got = _sim_thetas(kind, schedule, b, steps, lr, k / J, mom)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    # the pre-fix behavior: the generic eps/a_prev/s_prev freeze, which
    # is correct for (reg)topk but corrupts this kind's a_prev slot
    cls = {"dgc": DGC, "coordtopk": CoordTopK}[kind]
    orig = cls.on_dropped
    cls.on_dropped = Sparsifier.on_dropped
    try:
        buggy = _sim_thetas(kind, schedule, b, steps, lr, k / J, mom)
    finally:
        cls.on_dropped = orig
    assert np.abs(buggy - want).max() > 1e-3
