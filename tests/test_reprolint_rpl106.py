"""RPL106 fixtures: SparsifierState slot discipline."""
import textwrap

from tools.reprolint import lint_paths


def _lint(tmp_path, source, rel="fixture.py"):
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    viols, n_files = lint_paths(
        [str(f)], select=["RPL106"], repo_root=str(tmp_path)
    )
    assert n_files == 1
    return viols


_CONSTRUCT = """
    from repro.core.sparsify import SparsifierState

    def rewrite_dropped(a_stack, new_ws):
        return SparsifierState(
            eps=a_stack, a_prev=new_ws.a_prev,
            s_prev=new_ws.s_prev, t=new_ws.t,
        )
    """


def test_constructor_outside_owner_flags(tmp_path):
    viols = _lint(tmp_path, _CONSTRUCT)
    assert len(viols) == 1
    assert viols[0].rule == "RPL106"
    assert "kind-specific" in viols[0].message


def test_constructor_in_owning_module_is_exempt(tmp_path):
    viols = _lint(tmp_path, _CONSTRUCT, rel="src/repro/core/sparsify.py")
    assert viols == []


def test_replace_of_unique_slots_flags(tmp_path):
    viols = _lint(
        tmp_path,
        """
        def freeze(old, new):
            return new._replace(a_prev=old.a_prev, s_prev=old.s_prev)
        """,
    )
    assert len(viols) == 1
    assert "a_prev=" in viols[0].message
    assert "s_prev=" in viols[0].message


def test_eps_only_replace_is_legal(tmp_path):
    # CompactState shares the ``eps`` field name; a bare eps replace
    # must not be claimed by this rule.
    viols = _lint(
        tmp_path,
        """
        def fold(st, delta):
            return st._replace(eps=st.eps - delta)
        """,
    )
    assert viols == []


def test_same_line_suppression(tmp_path):
    viols = _lint(
        tmp_path,
        """
        from repro.core.sparsify import SparsifierState

        def fabricate(z):
            return SparsifierState(  # reprolint: disable=RPL106
                eps=z, a_prev=z, s_prev=z, t=0,
            )
        """,
    )
    assert viols == []
