"""Hypothesis shim: the real library when installed, else a deterministic
seeded-sampling fallback so the property tests still *run* (not skip) on a
clean environment (ISSUE 1 satellite — the bare ``from hypothesis import``
used to error the whole ``pytest -x`` collection).

The fallback implements only the strategy surface this repo uses
(``integers``, ``floats``, ``lists``, ``booleans``, ``sampled_from``) and
draws ``max_examples`` pseudo-random samples from a fixed seed — weaker than
hypothesis (no shrinking, no edge-case bias beyond the endpoints we inject)
but the invariants are still exercised. Test modules import via::

    from _hyp import given, settings, st
"""
from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random

    class _Strategy:
        def __init__(self, draw, endpoints=()):
            self.draw = draw
            # endpoint samples are injected first (cheap edge-case bias)
            self.endpoints = list(endpoints)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: rng.randint(min_value, max_value),
                endpoints=[min_value, max_value],
            )

        @staticmethod
        def floats(
            min_value=None,
            max_value=None,
            allow_nan=False,
            allow_infinity=False,
            width=64,
            **_kw,
        ):
            lo = -1e6 if min_value is None else min_value
            hi = 1e6 if max_value is None else max_value
            return _Strategy(
                lambda rng: rng.uniform(lo, hi), endpoints=[lo, hi, 0.0]
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    st = _St()

    def settings(*, max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            # The last positional parameters are strategy-drawn; anything
            # before them stays visible to pytest (parametrize / fixtures).
            params = list(inspect.signature(fn).parameters)
            free = [p for p in params if p not in kw_strategies]
            n_fix = len(free) - len(strategies)
            fixture_params, strat_names = free[:n_fix], free[n_fix:]

            def wrapper(**fixture_kwargs):
                rng = random.Random(0xE9)
                n = getattr(wrapper, "_max_examples", 20)
                ran = 0
                # endpoint passes first (single-strategy case only: combined
                # endpoint products explode for multi-arg tests)
                if len(strategies) == 1 and not kw_strategies:
                    for ep in strategies[0].endpoints:
                        fn(**fixture_kwargs, **{strat_names[0]: ep})
                        ran += 1
                while ran < n:
                    drawn = {
                        nm: s.draw(rng)
                        for nm, s in zip(strat_names, strategies, strict=True)
                    }
                    kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(**fixture_kwargs, **drawn, **kw)
                    ran += 1

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = inspect.Signature(
                [
                    inspect.Parameter(
                        p, inspect.Parameter.POSITIONAL_OR_KEYWORD
                    )
                    for p in fixture_params
                ]
            )
            return wrapper

        return deco
