"""RPL105 fixtures: codec/collective completeness via import-and-inspect.

The clean fixture is the repo itself — the rule runs against the same
binary the tests import, so a green run here certifies the live
registries. The true-positive seeds a deliberately broken subclass and
checks every facet of the surface contract fires.
"""
import gc
import os

from tools.reprolint.rules import rpl105

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_repo_registries_are_clean():
    assert rpl105.check_project(REPO_ROOT) == []


def test_incomplete_codec_subclass_flags():
    from repro.comm.codec import Codec

    class HalfCodec(Codec):  # missing decode/wire_bits, unregistered
        name = "half"
        supports_fused = True  # ...but no encode_fused

        def encode(self, vals, idx, length):
            return {"vals": vals}

    try:
        viols = [
            v
            for v in rpl105.check_project(REPO_ROOT)
            if "HalfCodec" in v.message
        ]
        msgs = " | ".join(v.message for v in viols)
        assert "does not define decode()" in msgs
        assert "does not define wire_bits()" in msgs
        assert "supports_fused=True" in msgs  # raising base encode_fused
        assert "not registered" in msgs
    finally:
        del HalfCodec
        gc.collect()
    assert rpl105.check_project(REPO_ROOT) == []


def test_dead_fused_path_flags():
    from repro.comm.codec import Codec

    class DeadFused(Codec):  # encode_fused present but supports_fused False
        name = "dead_fused"
        supports_fused = False

        def encode(self, vals, idx, length):
            return {"vals": vals}

        def encode_fused(self, vals, idx, length):
            return self.encode(vals, idx, length)

        def decode(self, payload, length):
            return payload["vals"], payload["vals"]

        def wire_bits(self, length, k):
            return 64 * k

    try:
        viols = [
            v
            for v in rpl105.check_project(REPO_ROOT)
            if "DeadFused" in v.message
        ]
        assert any("dead fused path" in v.message for v in viols)
    finally:
        del DeadFused
        gc.collect()


def test_incomplete_collective_subclass_flags():
    from repro.comm.collectives import Collective

    class HalfCollective(Collective):  # no shard(), unregistered
        name = "half_coll"

        def reference(self, codec, payloads, weights, length,
                      participation=None):
            return None

    try:
        viols = [
            v
            for v in rpl105.check_project(REPO_ROOT)
            if "HalfCollective" in v.message
        ]
        msgs = " | ".join(v.message for v in viols)
        assert "does not define shard()" in msgs
        assert "not registered" in msgs
    finally:
        del HalfCollective
        gc.collect()
