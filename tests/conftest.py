"""Make ``pytest tests/`` work without PYTHONPATH=src (and never touch
jax device state here — the dry-run owns XLA_FLAGS, per DESIGN.md)."""
import os
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))
