"""Make ``pytest tests/`` work without PYTHONPATH=src (and never touch
jax device state here — the dry-run owns XLA_FLAGS, per DESIGN.md)."""
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))
# repo root too, so the reprolint test modules can import ``tools.reprolint``
if ROOT not in sys.path:
    sys.path.insert(1, ROOT)
