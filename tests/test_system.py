"""System-level coverage: sharding resolution across all archs, the HLO
cost walker, and expert-parallel MoE numerics."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core.distributed import build_plan, shapes_and_axes
from repro.launch import hlo_cost
from repro.models import get_family
from repro.nn import sharding as shlib


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH16 = _FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", sorted(cfglib.ARCHS))
def test_full_arch_sharding_resolves(arch):
    """Every FULL config's parameter tree resolves to valid specs: sharded
    dims divide evenly, at most one mesh axis per tensor dim, and the
    tensor-parallel plan produces consistent local shapes."""
    cfg = cfglib.get_config(arch).replace(dtype="bfloat16")
    mod = get_family(cfg)
    shapes, axes = shapes_and_axes(mod, cfg)
    specs = shlib.tree_specs(shapes, axes, MESH16, dp_axes=("data",))
    plan = build_plan(shapes, specs, MESH16, 0.001)
    n_sharded = 0
    for leaf, spec, p in zip(
        jax.tree.leaves(shapes), jax.tree.leaves(specs),
        jax.tree.leaves(plan, is_leaf=lambda x: hasattr(x, "local_shape")),
        strict=True,
    ):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes_ = entry if isinstance(entry, tuple) else (entry,)
            div = int(np.prod([MESH16.shape[a] for a in axes_]))
            assert leaf.shape[dim] % div == 0, (arch, leaf.shape, spec)
            n_sharded += 1
        assert p.local_len == int(np.prod(p.local_shape) or 1)
        assert 1 <= p.k <= p.local_len
    # tensor parallelism must actually engage for every full arch
    assert n_sharded > 0, f"{arch}: nothing sharded on the model axis"


def test_total_param_counts_match_analytic():
    """Abstract init param counts vs the roofline's analytic count (±5%,
    analytic ignores norms/biases)."""
    from benchmarks.roofline import count_params

    for arch in ("qwen2.5-3b", "mixtral-8x7b", "mamba2-780m", "granite-3-8b"):
        cfg = cfglib.get_config(arch)
        shapes, _ = shapes_and_axes(get_family(cfg), cfg)
        total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = count_params(cfg)["total"]
        assert abs(total - analytic) / analytic < 0.05, (arch, total, analytic)


# ---------------------------------------------------------------------------
# HLO cost walker unit test (synthetic HLO)
# ---------------------------------------------------------------------------
SYNTH_HLO = textwrap.dedent(
    """
    HloModule synth

    %body (p.0: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
      %p.0 = (s32[], f32[8,4]) parameter(0)
      %iter = s32[] get-tuple-element(%p.0), index=0
      %one = s32[] constant(1)
      %next = s32[] add(%iter, %one)
      %x = f32[8,4] get-tuple-element(%p.0), index=1
      %w = f32[4,4] constant({...})
      %y = f32[8,4] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %r = f32[8,4] all-reduce(%y), replica_groups={}, to_apply=%sum
      ROOT %t = (s32[], f32[8,4]) tuple(%next, %r)
    }

    %cond (p.1: (s32[], f32[8,4])) -> pred[] {
      %p.1 = (s32[], f32[8,4]) parameter(0)
      %i = s32[] get-tuple-element(%p.1), index=0
      %n = s32[] constant(7)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[8,4]) -> (s32[], f32[8,4]) {
      %arg = f32[8,4] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,4]) tuple(%zero, %arg)
      ROOT %w0 = (s32[], f32[8,4]) while(%init), condition=%cond, body=%wbody
    }
    """
).replace("%wbody", "%body")


def test_hlo_cost_walker_multiplies_trip_counts():
    res = hlo_cost.analyze(SYNTH_HLO)
    # dot: 2 * (8*4) * 4 = 256 flops per iteration x 7 trips
    assert res["flops"] == pytest.approx(256 * 7)
    # all-reduce result bytes: 8*4*4 = 128 B x 7 trips
    assert res["collective_bytes"]["all-reduce"] == pytest.approx(128 * 7)


def test_hlo_cost_walker_on_real_program():
    fn = jax.jit(lambda x: jax.lax.scan(
        lambda c, _: (c @ jnp.ones((8, 8), jnp.float32), None), x, None,
        length=5,
    )[0])
    txt = fn.lower(jnp.ones((8, 8))).compile().as_text()
    res = hlo_cost.analyze(txt)
    # 2*8*8*8 = 1024 flops per step x 5 steps (allow fusion slack)
    assert res["flops"] >= 1024 * 5


# ---------------------------------------------------------------------------
# expert-parallel MoE numerics (vs tensor layout) on a multi-device mesh
# ---------------------------------------------------------------------------
def test_expert_parallel_matches_tensor_layout():
    from tests.test_distributed import run_sub

    code = textwrap.dedent(
        """
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        from repro.models import ModelConfig, get_family, make_batch
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.nn import sharding as shlib

        outs = {}
        for par in ("tensor", "expert"):
            cfg = ModelConfig(
                name="moe-tiny", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab=256,
                n_experts=4, moe_top_k=2, moe_group_size=64,
                capacity_factor=8.0, remat=False, moe_parallelism=par)
            mod = get_family(cfg)
            params, axes = mod.init(jax.random.PRNGKey(0), cfg)
            batch = make_batch(cfg, 4, 16, key=jax.random.PRNGKey(1))
            specs = shlib.tree_specs(params, axes, mesh, dp_axes=("data",))
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                              is_leaf=lambda x: isinstance(x, P))
            params = jax.device_put(params, sh)
            with mesh:
                loss, _ = jax.jit(
                    lambda p, b: mod.loss_fn(p, cfg, b))(params, batch)
            outs[par] = float(loss)
        print(json.dumps(outs))
        """
    )
    res = run_sub(code)
    assert res["tensor"] == pytest.approx(res["expert"], rel=1e-4)
