"""Runtime guards paired with reprolint (ISSUE 7 tentpole):

* retrace counters (the chex ``assert_max_traces`` idiom, implemented
  locally so CI needs no extra dependency) asserting the
  ``DistributedSim`` and ``make_sparsify_aggregate`` round loops compile
  exactly once across rounds and participation schedules — a silent
  per-round retrace is a throughput bug no numeric test catches;
* a shard-safety smoke running every collective under a *renamed* mesh
  axis, proving no hardcoded axis name survives anywhere in the payload
  path (the runtime twin of RPL102);
* ``compact_select`` fastpath on/off/auto routing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import make_mesh, shard_map
from repro.core.simulator import DistributedSim
from repro.core.sparsify import SparsifierConfig


def counting(fn):
    """Python-side trace counter: the body runs once per trace, so the
    counter equals the number of compilations of the jitted wrapper."""
    calls = {"n": 0}

    def wrapper(*args, **kwargs):
        calls["n"] += 1
        return fn(*args, **kwargs)

    return wrapper, calls


# ---------------------------------------------------------------------------
# retrace guards
# ---------------------------------------------------------------------------
N, L = 4, 64


def _sim(collective, kind, participation=None, **kw):
    return DistributedSim(
        grad_fn=lambda theta, i: theta * (1.0 + i) - 0.1,
        n_workers=N,
        length=L,
        sparsifier_cfg=SparsifierConfig(kind=kind, sparsity=8 / L),
        aggregation=collective,
        participation=participation,
        **kw,
    )


@pytest.mark.parametrize(
    "collective,kind,participation",
    [
        ("dense_allreduce", "topk", None),
        ("sparse_allgather", "regtopk", None),
        (
            "sparse_allgather",
            "regtopk",
            comm.Participation("round_robin", n_stragglers=1),
        ),
        (
            "sparse_allgather",
            "regtopk",
            comm.Participation("bernoulli", drop_rate=0.5, seed=3),
        ),
        (
            "dense_allreduce",
            "regtopk",
            comm.Participation(
                "stale", n_stragglers=1, staleness=2, discount=0.5
            ),
        ),
    ],
    ids=["dense-topk", "spa-regtopk", "round_robin", "bernoulli", "stale"],
)
def test_sim_round_loop_compiles_once(collective, kind, participation):
    """5 rounds of the simulator step under one jit wrapper: exactly one
    trace, including when the participation mask varies per round (the
    round index is part of traced state, so schedule changes must not
    retrace)."""
    sim = _sim(collective, kind, participation)
    counted, calls = counting(sim.step_fn)
    step = jax.jit(counted)
    state = sim.init(jnp.linspace(1.0, 2.0, L))
    for _ in range(5):
        state, g_agg = step(state)
    jax.block_until_ready(g_agg)
    assert calls["n"] == 1, (
        f"step_fn retraced: {calls['n']} traces over 5 rounds"
    )
    assert int(state.step) == 5


def test_sim_distinct_configs_compile_separately():
    """The guard has teeth: a genuinely different config is a different
    compilation (counter 1 each), not a cache hit on the first."""
    for kind in ("topk", "regtopk"):
        sim = _sim("sparse_allgather", kind)
        counted, calls = counting(sim.step_fn)
        # one jit per config is the point here
        step = jax.jit(counted)  # reprolint: disable=RPL104
        state = sim.init(jnp.ones((L,)))
        for _ in range(3):
            state, _ = step(state)
        assert calls["n"] == 1


def test_make_sparsify_aggregate_round_loop_compiles_once():
    """4 rounds through the shard_map aggregation on an in-process (1,1)
    mesh: one trace, with the compact state's round counter advancing."""
    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        init_sparsifier_state,
        make_sparsify_aggregate,
    )

    mesh = make_mesh((1, 1), ("data", "model"))
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=8 / 256),
        codec="coo_fp32",
        collective="sparse_allgather",
        dp_axes=("data",),
    )
    plan = {"w": LeafPlan((256,), (256,), 256, 8, P(None), fused=False)}
    state, _specs = init_sparsifier_state(
        plan, 1, mesh, ("data",), jnp.float32
    )
    spa = make_sparsify_aggregate(
        mesh, plan, {"w": P(None)}, _specs, dist, 1
    )
    counted, calls = counting(spa)
    step = jax.jit(counted)
    grads = {"w": jnp.linspace(-1.0, 1.0, 256).reshape(1, 256)}
    with mesh:
        for _ in range(4):
            agg, state = step(grads, state)
    jax.block_until_ready(agg)
    assert calls["n"] == 1, (
        f"make_sparsify_aggregate retraced: {calls['n']} traces in 4 rounds"
    )
    assert int(state["w"].t[0]) == 4


# ---------------------------------------------------------------------------
# shard-safety smoke: renamed mesh axis (runtime twin of RPL102)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "sname", ["dense_allreduce", "sparse_allgather", "hierarchical"]
)
def test_collectives_survive_renamed_axis(sname):
    """Every collective's shard form must run under a mesh whose axis is
    named something no repo module ever mentions — any hardcoded axis
    name in the payload path would raise NameError at trace time."""
    L, k = 96, 8
    axis = "zz9_renamed"
    codec = comm.get_codec("coo_fp32")
    strategy = comm.get_collective(sname)
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    idx = jnp.asarray(rng.choice(L, size=(k,), replace=False), jnp.int32)
    payload = codec.encode(vals, idx, L)
    stacked = jax.tree.map(lambda x: x[None], payload)
    ref = strategy.reference(codec, stacked, jnp.ones((1,)), L)

    mesh = make_mesh((1,), (axis,))
    in_specs = jax.tree.map(
        lambda x: P(*((axis,) + (None,) * x.ndim)), payload
    )

    def body(p):
        local = jax.tree.map(lambda x: x[0], p)
        return strategy.shard(codec, local, L, (axis,), 1.0)

    with mesh:
        got = shard_map(
            body,
            mesh=mesh,
            in_specs=(in_specs,),
            out_specs=P(None),
            check_vma=False,
        )(stacked)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-6, atol=1e-7
    )


# ---------------------------------------------------------------------------
# compact_select fastpath routing
# ---------------------------------------------------------------------------
FUSABLE_L, FUSABLE_K = 8192, 8


def _compact_inputs(dtype=jnp.float32):
    from repro.core import compact as C

    st = C.compact_init(FUSABLE_L, FUSABLE_K, dtype=dtype)
    g = jnp.asarray(
        np.random.default_rng(1).normal(size=(FUSABLE_L,)), dtype
    )
    return st, g


def _route_recorder(monkeypatch):
    import repro.comm.fastpath as fp

    hits = {"n": 0}

    def fake_fused(scfg, st, g, k, *, interpret=None):
        hits["n"] += 1
        a = st.eps + g.astype(st.eps.dtype)
        return a, jnp.zeros((k,), a.dtype), jnp.zeros((k,), jnp.int32)

    monkeypatch.setattr(fp, "fused_compact_select", fake_fused)
    return hits


def test_fastpath_on_routes_to_fused(monkeypatch):
    from repro.core import compact as C

    hits = _route_recorder(monkeypatch)
    cfg = SparsifierConfig(kind="topk", sparsity=FUSABLE_K / FUSABLE_L)
    st, g = _compact_inputs()
    C.compact_select(cfg, st, g, FUSABLE_K, fastpath="on")
    assert hits["n"] == 1


def test_fastpath_off_and_none_stay_dense(monkeypatch):
    from repro.core import compact as C

    hits = _route_recorder(monkeypatch)
    cfg = SparsifierConfig(kind="topk", sparsity=FUSABLE_K / FUSABLE_L)
    st, g = _compact_inputs()
    a1, v1, i1 = C.compact_select(cfg, st, g, FUSABLE_K, fastpath="off")
    a2, v2, i2 = C.compact_select(cfg, st, g, FUSABLE_K, fastpath=None)
    assert hits["n"] == 0
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_fastpath_auto_declines_off_tpu(monkeypatch):
    from repro.core import compact as C

    hits = _route_recorder(monkeypatch)
    cfg = SparsifierConfig(kind="topk", sparsity=FUSABLE_K / FUSABLE_L)
    st, g = _compact_inputs()
    C.compact_select(cfg, st, g, FUSABLE_K, fastpath="auto")
    if jax.default_backend() != "tpu":
        assert hits["n"] == 0


def test_fastpath_on_declines_non_fusable_state(monkeypatch):
    # non-f32 state never fuses (the kernel scores in f32 — not
    # bit-for-bit against a bf16 dense path), even when forced "on".
    from repro.core import compact as C

    hits = _route_recorder(monkeypatch)
    cfg = SparsifierConfig(kind="topk", sparsity=FUSABLE_K / FUSABLE_L)
    st, g = _compact_inputs(dtype=jnp.bfloat16)
    C.compact_select(cfg, st, g, FUSABLE_K, fastpath="on")
    assert hits["n"] == 0


def test_fastpath_unknown_mode_raises():
    from repro.core import compact as C

    cfg = SparsifierConfig(kind="topk", sparsity=FUSABLE_K / FUSABLE_L)
    st, g = _compact_inputs()
    with pytest.raises(ValueError, match="unknown fastpath"):
        C.compact_select(cfg, st, g, FUSABLE_K, fastpath="bogus")
