"""Distributed runtime integration tests.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps seeing 1 device (per the dry-run isolation contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compact import compact_finalize, compact_select
from repro.core.sparsify import SparsifierConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=480,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# compact-state equivalence with the dense simulator algebra
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "kind,y",
    [("topk", 1.0), ("regtopk", 1.0), ("regtopk", 0.5), ("regtopk", 2.0)],
)
def test_compact_matches_dense_state(kind, y):
    """Dense <-> compact equivalence, including the Remark-4 prior exponent
    (regression: compact_select silently ignored cfg.y)."""
    L, k, steps = 64, 8, 5
    cfg = SparsifierConfig(kind=kind, sparsity=k / L, mu=1.5, omega=0.1, y=y)
    from repro.core.compact import compact_init, reference_step

    st = compact_init(L, k)
    key = jax.random.PRNGKey(0)
    g_prev_dense = jnp.zeros(L)
    for _t in range(steps):
        key, sk = jax.random.split(key)
        g = jax.random.normal(sk, (L,))
        # dense reference on the reconstructed state
        ghat_ref, mask_ref, _ = reference_step(cfg, st, g, g_prev_dense, k)
        a, vals, idx = compact_select(cfg, st, g, k)
        ghat = jnp.zeros(L).at[idx].set(vals)
        np.testing.assert_allclose(
            np.asarray(ghat), np.asarray(ghat_ref), rtol=1e-5, atol=1e-6
        )
        agg = 0.1 * ghat  # arbitrary aggregate
        st = compact_finalize(st, a, vals, idx, agg)
        g_prev_dense = agg


def test_compact_threshold_selector_routes_not_drops():
    """Regression: compact_select ignored SparsifierConfig.selector — the
    distributed runtime always ran exact top-k whatever the config said.
    selector='threshold' must route through the bisection mask +
    mask_to_payload (same selected set when the mask has no ties), and
    unknown selectors must raise, not silently fall back."""
    import dataclasses

    from repro.core.compact import compact_init

    L, k = 64, 8
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (L,))
    cfg = SparsifierConfig(kind="regtopk", sparsity=k / L, mu=1.5, omega=0.1)
    st = compact_init(L, k)
    a_e, v_e, i_e = compact_select(cfg, st, g, k)
    a_t, v_t, i_t = compact_select(
        dataclasses.replace(cfg, selector="threshold"), st, g, k
    )
    np.testing.assert_allclose(np.asarray(a_t), np.asarray(a_e))
    # same coordinate set (payload order may differ)
    assert set(np.asarray(i_t).tolist()) == set(np.asarray(i_e).tolist())
    dense_e = np.zeros(L)
    dense_e[np.asarray(i_e)] = np.asarray(v_e)
    dense_t = np.zeros(L)
    dense_t[np.asarray(i_t)] = np.asarray(v_t)
    np.testing.assert_allclose(dense_t, dense_e, rtol=1e-6)
    with pytest.raises(ValueError, match="selector"):
        compact_select(
            dataclasses.replace(cfg, selector="bogus"), st, g, k
        )


def test_compact_zero_gradient_round_threshold_selector():
    """A zero gradient round with the threshold selector must produce an
    all-(0, 0) payload (scatter no-op), not ship the whole vector."""
    import dataclasses

    from repro.core.compact import compact_init

    L, k = 32, 4
    cfg = SparsifierConfig(
        kind="regtopk", sparsity=k / L, selector="threshold"
    )
    st = compact_init(L, k)
    a, vals, idx = compact_select(cfg, st, jnp.zeros(L), k)
    np.testing.assert_array_equal(np.asarray(vals), 0.0)
    np.testing.assert_array_equal(np.asarray(idx), 0)


def test_compact_exact_padding_never_destroys_live_coordinates():
    """Regression: with fewer than k nonzero scores, the exact selector's
    padding slots must not collide with a genuinely selected coordinate 0
    (a duplicate-index scatter-set silently dropped its gradient from
    both the aggregate and error feedback)."""
    from repro.core.compact import compact_init

    L, k = 8, 4
    g = jnp.zeros(L).at[jnp.array([0, 3])].set(jnp.array([5.0, 3.0]))
    cfg = SparsifierConfig(kind="topk", sparsity=k / L)
    st = compact_init(L, k)
    a, vals, idx = compact_select(cfg, st, g, k)
    # payload indices are distinct -> scatter set/add agree downstream
    assert len(set(np.asarray(idx).tolist())) == k
    ghat = np.zeros(L)
    np.add.at(ghat, np.asarray(idx), np.asarray(vals))
    np.testing.assert_allclose(ghat, np.asarray(g))  # 5.0 survives
    st2 = compact_finalize(st, a, vals, idx, jnp.zeros(L))
    # error conservation: eps' + sent == a, for every coordinate
    np.testing.assert_allclose(
        np.asarray(st2.eps) + ghat, np.asarray(a), rtol=1e-6
    )
    # the (0, j)-padded threshold payload conserves too
    mask = jnp.zeros(L).at[3].set(1.0)  # cardinality 1 < k
    from repro.core.selectors import mask_to_payload

    pv, pi = mask_to_payload(mask, a, k)
    st3 = compact_finalize(st, a, pv, pi, jnp.zeros(L))
    sent = np.zeros(L)
    np.add.at(sent, np.asarray(pi), np.asarray(pv))
    np.testing.assert_allclose(
        np.asarray(st3.eps) + sent, np.asarray(a), rtol=1e-6
    )
    assert float(st3.eps[0]) == 5.0  # unsent coordinate 0 stays in eps


def test_compact_cyclic_covers_all_coordinates():
    L, k = 20, 6
    cfg = SparsifierConfig(kind="cyclic", sparsity=k / L)
    from repro.core.compact import compact_init

    st = compact_init(L, k)
    seen = set()
    for _t in range(-(-L // k) + 1):
        g = jnp.ones(L)
        a, vals, idx = compact_select(cfg, st, g, k)
        seen.update(np.asarray(idx).tolist())
        st = compact_finalize(st, a, vals, idx, jnp.zeros(L))
    assert seen == set(range(L))


# ---------------------------------------------------------------------------
# multi-device integration (subprocess)
# ---------------------------------------------------------------------------
SUB_TEMPLATE = textwrap.dedent(
    """
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    from repro.models import ModelConfig, get_family
    from repro.core.distributed import DistConfig, assemble, init_sparsifier_state
    from repro.core.sparsify import SparsifierConfig
    from repro.optim import OptConfig, make_optimizer
    from repro.data import TokenPipeline

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=256, remat=False)
    mod = get_family(cfg)

    def train(kind, agg, steps=25, sparsity=0.05, fastpath="off", **dkw):
        dist = DistConfig(
            sparsifier=SparsifierConfig(kind=kind, sparsity=sparsity, mu=1.0),
            optimizer=OptConfig(kind="adam", learning_rate=3e-3),
            aggregation=agg, microbatches=2, dp_axes=("data",),
            fastpath=fastpath, **dkw)
        asm = assemble(mod, cfg, dist, mesh)
        params, _ = mod.init(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(dist.optimizer)
        opt_state = opt.init(params)
        sp_state, _ = init_sparsifier_state(asm.plan, 4, mesh, ("data",),
                                            jnp.float32)
        pipe = TokenPipeline(cfg, global_batch=8, seq=32)
        step = jax.jit(asm.train_step)
        losses = []
        with mesh:
            for t in range(steps):
                params, opt_state, sp_state, m = step(
                    params, opt_state, sp_state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        return losses, params

    {BODY}
    """
)


def test_sparse_equals_dense_aggregation_multidevice():
    body = """
l1, p1 = train("regtopk", "dense_allreduce")
l2, p2 = train("regtopk", "sparse_allgather")
d = max(abs(a - b) for a, b in zip(l1, l2))
print(json.dumps({"max_loss_diff": d, "decreased": l1[-1] < l1[0]}))
"""
    res = run_sub(SUB_TEMPLATE.replace("{BODY}", body))
    assert res["max_loss_diff"] < 1e-4
    assert res["decreased"]


def test_fused_fastpath_training_equivalence_multidevice():
    """ISSUE 5 acceptance: dense↔fused training equivalence in the real
    shard_map runtime — the fused select→encode pipeline (interpret-mode
    Pallas inside an 8-device mesh) reproduces the unfused losses exactly
    (the selection payload is bit-for-bit, so trajectories cannot
    diverge)."""
    body = """
l1, p1 = train("regtopk", "sparse_allgather", steps=6, sparsity=0.002)
l2, p2 = train("regtopk", "sparse_allgather", steps=6, sparsity=0.002,
               fastpath="on")
import jax as _j
pdiff = max(float(abs(a - b).max())
            for a, b in zip(_j.tree.leaves(p1), _j.tree.leaves(p2)))
d = max(abs(a - b) for a, b in zip(l1, l2))
print(json.dumps({"max_loss_diff": d, "max_param_diff": pdiff}))
"""
    res = run_sub(SUB_TEMPLATE.replace("{BODY}", body))
    assert res["max_loss_diff"] == 0.0
    assert res["max_param_diff"] == 0.0


def test_bucketed_overlap_bitforbit_multidevice():
    """ISSUE 10 acceptance: the bucketed overlap schedule is a pure
    reorder — ``overlap='buckets:3'`` reproduces the synchronous
    ``overlap='off'`` losses and parameters bit-for-bit on a real
    8-device shard_map mesh (the timeline metric itself is covered in
    ``tests/test_overlap.py``)."""
    body = """
l1, p1 = train("regtopk", "sparse_allgather", steps=6)
l2, p2 = train("regtopk", "sparse_allgather", steps=6,
               overlap="buckets:3")
import jax as _j
pdiff = max(float(abs(a - b).max())
            for a, b in zip(_j.tree.leaves(p1), _j.tree.leaves(p2)))
d = max(abs(a - b) for a, b in zip(l1, l2))
print(json.dumps({"max_loss_diff": d, "max_param_diff": pdiff}))
"""
    res = run_sub(SUB_TEMPLATE.replace("{BODY}", body))
    assert res["max_loss_diff"] == 0.0
    assert res["max_param_diff"] == 0.0


def test_compact_select_fastpath_multi_round_parity():
    """compact_select(fastpath="on") == the dense path, bit-for-bit, over
    an evolving multi-round regtopk state (posterior statistics scattered
    from the compact k-vectors must reproduce the k-vector score math
    exactly)."""
    L, k = 10_000, 16
    cfg = SparsifierConfig(kind="regtopk", mu=1.0, omega=0.125)
    from repro.core.compact import compact_init

    st = compact_init(L, k)
    key = jax.random.PRNGKey(3)
    for _t in range(4):
        key, sk = jax.random.split(key)
        g = jax.random.normal(sk, (L,))
        a1, v1, i1 = compact_select(cfg, st, g, k)
        a2, v2, i2 = compact_select(cfg, st, g, k, fastpath="on")
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        agg = 0.125 * jnp.zeros(L).at[i1].add(v1)
        st = compact_finalize(st, a1, v1, i1, agg)


def test_fused_plan_validation_and_dtype_gate():
    """A plan hand-marked fused on a non-fusable wire fails fast at
    aggregation build (not deep inside shard_map); fastpath='on' with a
    bf16 state raises (the fused kernel scores in f32 — not bit-for-bit
    against a bf16 unfused path) while 'auto' quietly declines."""
    import dataclasses

    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh
    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        leaf_fastpath,
        make_sparsify_aggregate,
        sparsifier_state_shapes,
    )

    mesh = make_mesh((1, 1), ("data", "model"))
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.002),
        codec="bitmap_dense", collective="sparse_allgather",
        dp_axes=("data",), fastpath="on",
    )
    plan = {"w": LeafPlan((8192,), (8192,), 8192, 17, P(None), fused=True)}
    _, sspecs = sparsifier_state_shapes(plan, 1, mesh, ("data",), jnp.float32)
    with pytest.raises(ValueError, match="not fusable"):
        make_sparsify_aggregate(
            mesh, plan, {"w": P(None)}, sspecs, dist, 1
        )
    bf16_on = dataclasses.replace(
        dist, codec="coo_fp32", state_dtype="bfloat16"
    )
    with pytest.raises(ValueError, match="float32"):
        bf16_on.resolved_fastpath()
    bf16_auto = dataclasses.replace(bf16_on, fastpath="auto")
    assert bf16_auto.resolved_fastpath() == "off"
    # the dtype gate also zeroes the per-leaf resolution
    assert not leaf_fastpath(plan["w"], bf16_auto)


@pytest.mark.parametrize("kind", ["topk", "cyclic", "none"])
def test_all_kinds_train_multidevice(kind):
    body = f"""
l, p = train("{kind}", "dense_allreduce", steps=20)
print(json.dumps({{"first": l[0], "last": l[-1]}}))
"""
    res = run_sub(SUB_TEMPLATE.replace("{BODY}", body))
    assert np.isfinite(res["last"])
    assert res["last"] < res["first"]


def test_checkpoint_roundtrip_multidevice():
    body = """
import tempfile, os
from repro.checkpoint import save, restore
l, p = train("regtopk", "dense_allreduce", steps=5)
d = tempfile.mkdtemp()
save(d, p, metadata={"step": 5})
p2 = restore(d, p)
same = all(bool(jnp.allclose(a, b)) for a, b in
           zip(jax.tree.leaves(p), jax.tree.leaves(p2)))
print(json.dumps({"same": same}))
"""
    res = run_sub(SUB_TEMPLATE.replace("{BODY}", body))
    assert res["same"]


def test_dryrun_mini_multidevice():
    """Mini dry-run: lower+compile a reduced arch on a (2,4) mesh and check
    the cost walker sees nonzero flops and collectives."""
    code = textwrap.dedent(
        """
        import json
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        from repro import configs as cfglib
        from repro.models import get_family, input_specs
        from repro.core.distributed import DistConfig, assemble
        from repro.core.sparsify import SparsifierConfig
        from repro.optim import OptConfig, make_optimizer
        from repro.launch import hlo_cost
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = cfglib.get_config("qwen2.5-3b").smoke_variant()
        mod = get_family(cfg)
        dist = DistConfig(
            sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.01),
            optimizer=OptConfig(kind="adam"),
            aggregation="sparse_allgather", microbatches=2,
            dp_axes=("data",))
        asm = assemble(mod, cfg, dist, mesh)
        opt_shape = jax.eval_shape(
            lambda p: make_optimizer(dist.optimizer).init(p), asm.params_shape)
        sh = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        batch = input_specs(cfg, 8, 32, kind="train")
        bs = jax.tree.map(lambda s: NamedSharding(mesh, P("data")), batch)
        opt_specs = {"step": P(), "m": asm.param_specs, "v": asm.param_specs}
        with mesh:
            lowered = jax.jit(
                asm.train_step,
                in_shardings=(sh(asm.param_specs), sh(opt_specs),
                              sh(asm.state_specs), bs),
            ).lower(asm.params_shape, opt_shape, asm.state_shapes, batch)
            compiled = lowered.compile()
        res = hlo_cost.analyze(compiled.as_text())
        mem = compiled.memory_analysis()
        print(json.dumps({
            "flops": res["flops"],
            "coll": res["collective_bytes"]["total"],
            "peak": getattr(mem, "peak_memory_in_bytes", 0) or 0,
        }))
        """
    )
    res = run_sub(code)
    assert res["flops"] > 1e6
    assert res["coll"] > 0
    # the CPU backend of older jaxlibs reports no memory analysis (peak 0);
    # only assert when the backend provides the number.
    assert res["peak"] >= 0
    if res["peak"]:
        assert res["peak"] > 1e5


def test_train_cli_checkpoint_resume(tmp_path):
    """End-to-end launcher: train -> checkpoint -> resume continues."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    ckpt = str(tmp_path / "ck")
    base = [sys.executable, "-m", "repro.launch.train",
            "--arch", "paper-resnet-proxy", "--smoke", "--steps", "4",
            "--global-batch", "2", "--seq", "16", "--log-every", "2"]
    r1 = subprocess.run([*base, "--checkpoint", ckpt],
                        capture_output=True, text=True, env=env, timeout=480)
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert "checkpointed" in r1.stdout
    r2 = subprocess.run([*base, "--resume", ckpt],
                        capture_output=True, text=True, env=env, timeout=480)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout
    assert "step     7" in r2.stdout or "step 7" in r2.stdout.replace("  ", " ")


def test_spa_participation_round_loop_compiles_once_multidevice():
    """Retrace guard (ISSUE 7): the shard_map aggregation under a
    round_robin participation schedule on a real 4-worker mesh compiles
    exactly once across rounds — the rotating drop set is a function of
    the *traced* round counter, never a fresh compilation."""
    code = textwrap.dedent("""
        import json

        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.comm import Participation
        from repro.compat import make_mesh
        from repro.core.distributed import (
            DistConfig,
            LeafPlan,
            init_sparsifier_state,
            make_sparsify_aggregate,
        )
        from repro.core.sparsify import SparsifierConfig

        mesh = make_mesh((4, 1), ("data", "model"))
        dist = DistConfig(
            sparsifier=SparsifierConfig(kind="regtopk", sparsity=8 / 256),
            codec="coo_fp32",
            collective="sparse_allgather",
            dp_axes=("data",),
            participation=Participation("round_robin", n_stragglers=1),
        )
        plan = {"w": LeafPlan((256,), (256,), 256, 8, P(None), fused=False)}
        state, specs = init_sparsifier_state(
            plan, 4, mesh, ("data",), jnp.float32
        )
        spa = make_sparsify_aggregate(mesh, plan, {"w": P(None)}, specs,
                                      dist, 4)
        calls = {"n": 0}

        def counted(g, s):
            calls["n"] += 1
            return spa(g, s)

        step = jax.jit(counted)
        grads = {"w": jnp.linspace(-1.0, 1.0, 4 * 256).reshape(4, 256)}
        with mesh:
            for _ in range(5):
                agg, state = step(grads, state)
        jax.block_until_ready(agg)
        print(json.dumps({"traces": calls["n"],
                          "t": int(state["w"].t[0])}))
    """)
    res = run_sub(code, devices=4)
    assert res["traces"] == 1, res
    assert res["t"] == 5


# ---------------------------------------------------------------------------
# per-coordinate weighting: reference <-> shard_map differentials (ISSUE 9)
# ---------------------------------------------------------------------------
COORD_SUB = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro import comm

    W, L, k = 8, 96, 9
    ks = jax.random.split(jax.random.PRNGKey(0), W)
    vals = jnp.stack([
        jnp.sign(jax.random.normal(kk, (k,)))
        * (0.5 + jax.random.uniform(kk, (k,))) for kk in ks])
    idx = jnp.stack([
        jnp.sort(jax.random.permutation(kk, L)[:k]) for kk in ks
    ]).astype(jnp.int32)
    weights = jnp.full((W,), 1.0 / W, jnp.float32)
    pmask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    mesh = make_mesh((W,), ("data",))
    out = {}
    for cname in ("coo_fp32", "coo_q8"):
        codec = comm.get_codec(cname)
        payloads = jax.vmap(lambda v, i: codec.encode(v, i, L))(vals, idx)
        in_specs = jax.tree.map(
            lambda x: P(*(("data",) + (None,) * (x.ndim - 1))), payloads)
        for sname in ("sparse_allgather", "hierarchical"):
            strat = comm.get_collective(sname)
            for tag, pm in (("full", None), ("partial", pmask)):
                w = weights if pm is None else comm.renormalize_weights(
                    weights, pm)
                ref_agg, ref_den = strat.reference_coord(
                    codec, payloads, weights, L, participation=pm)

                def body(p, m):
                    local = jax.tree.map(lambda x: x[0], p)
                    part = None if pm is None else m[0]
                    # shard form: each worker passes its renormalized
                    # weight entry (the runtime's _spa_leaf does the same)
                    wi = jax.lax.axis_index("data")
                    return strat.shard_coord(
                        codec, local, L, ("data",), w[wi],
                        participation=part)

                with mesh:
                    got_agg, got_den = shard_map(
                        body, mesh=mesh,
                        in_specs=(in_specs, P("data")),
                        out_specs=(P(None), P(None)), check_vma=False,
                    )(payloads, pmask)
                key = f"{cname}/{sname}/{tag}"
                out[key] = {
                    "agg_exact": bool((got_agg == ref_agg).all()),
                    "den_exact": bool((got_den == ref_den).all()),
                    "agg_close": float(jnp.abs(got_agg - ref_agg).max()),
                    "den_close": float(jnp.abs(got_den - ref_den).max()),
                    "finite": bool(jnp.isfinite(got_agg).all()),
                }
    print(json.dumps(out))
""")


def test_shard_coord_matches_reference_multidevice():
    """Coordinate weighting, reference vs in-shard_map form on a real
    8-device mesh: the flat-gather strategy reduces in worker-stack
    order through the shared scatter-add, so it is bit-for-bit;
    hierarchical regroups the sum (intra psum) and is equal to
    tolerance. Both codecs (incl. the lossy coo_q8, whose
    quantized-to-zero values must carry no sender mass) and both full
    and partial schedules."""
    res = run_sub(COORD_SUB)
    for key, r in res.items():
        assert r["finite"], (key, r)
        if "sparse_allgather" in key:
            assert r["agg_exact"] and r["den_exact"], (key, r)
        else:
            assert r["agg_close"] < 1e-6 and r["den_close"] < 1e-6, (key, r)


COORD_TRAIN_SUB = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    from repro.models import ModelConfig, get_family
    from repro.core.distributed import (DistConfig, assemble,
                                        init_sparsifier_state)
    from repro.core.sparsify import SparsifierConfig
    from repro.optim import OptConfig, make_optimizer
    from repro.data import TokenPipeline
    from repro import comm

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=256, remat=False)
    mod = get_family(cfg)

    def train(collective, weighting, participation=None, steps=6):
        dist = DistConfig(
            sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.05,
                                        mu=1.0),
            optimizer=OptConfig(kind="adam", learning_rate=3e-3),
            codec="coo_fp32", collective=collective, microbatches=1,
            dp_axes=("data",), participation=participation,
            weighting=weighting)
        asm = assemble(mod, cfg, dist, mesh)
        params, _ = mod.init(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(dist.optimizer)
        opt_state = opt.init(params)
        sp_state, _ = init_sparsifier_state(asm.plan, 4, mesh, ("data",),
                                            jnp.float32)
        pipe = TokenPipeline(cfg, global_batch=8, seq=32)
        step = jax.jit(asm.train_step)
        losses = []
        with mesh:
            for t in range(steps):
                params, opt_state, sp_state, m = step(
                    params, opt_state, sp_state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        return losses

    worker = train("sparse_allgather", "worker")
    coord_sparse = train("sparse_allgather", "coordinate")
    coord_dense = train("dense_allreduce", "coordinate")
    samp = comm.Participation("sampled", n_sampled=2, seed=3)
    coord_samp = train("sparse_allgather", "coordinate", samp)
    print(json.dumps({
        "coord_changes_training": max(
            abs(a - b) for a, b in zip(worker, coord_sparse)) > 0,
        "dense_vs_sparse": max(
            abs(a - b) for a, b in zip(coord_dense, coord_sparse)),
        "samp_finite": all(x == x for x in coord_samp),
        "finite": all(x == x for x in coord_sparse + coord_dense),
    }))
""")


def test_coordinate_weighting_trains_multidevice():
    """End-to-end shard_map runtime under weighting='coordinate': the
    dense and payload paths agree (same per-coordinate reduction through
    two different wire forms), training stays finite — including under
    S-of-N sampled participation — and the axis actually changes the
    numerics vs worker weighting."""
    res = run_sub(COORD_TRAIN_SUB)
    assert res["finite"] and res["samp_finite"]
    assert res["coord_changes_training"] is True
    assert res["dense_vs_sparse"] < 1e-4
