"""Unit + property tests for the sparsification core (paper Algorithms 1–2)."""
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import (
    DistributedSim,
    SparsifierConfig,
    dense_mean,
    exact_topk_mask,
    fixed_k_payload,
    make_sparsifier,
    mask_to_payload,
    scatter_add_payloads,
    sparsity_to_k,
    threshold_topk_mask,
)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# selectors
# ---------------------------------------------------------------------------
def test_exact_topk_mask_selects_largest():
    x = jnp.array([0.1, -5.0, 3.0, 0.0, -2.0])
    m = exact_topk_mask(jnp.abs(x), 2)
    np.testing.assert_array_equal(m, [0, 1, 1, 0, 0])


def test_exact_topk_edge_cases():
    """k <= 0 selects nothing; k >= J selects every *live* (nonzero-score)
    entry — a zero score carries no gradient and is never selected, the
    same contract the PR-2 fix gave the threshold selector."""
    x = jnp.arange(4.0)  # score 0.0 at index 0
    np.testing.assert_array_equal(exact_topk_mask(x, 0), jnp.zeros(4))
    np.testing.assert_array_equal(exact_topk_mask(x, 4), [0, 1, 1, 1])
    np.testing.assert_array_equal(exact_topk_mask(x, 9), [0, 1, 1, 1])
    np.testing.assert_array_equal(exact_topk_mask(jnp.zeros(4), 2), 0.0)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.floats(-1e3, 1e3, allow_nan=False, width=32), min_size=2, max_size=64
    ),
    st.integers(1, 64),
)
def test_exact_topk_cardinality_and_dominance(vals, k):
    """Selector invariant net (ISSUE 4 satellite): cardinality is exactly
    min(k, #nonzero scores) — never above k — zero scores are never
    selected, and every selected score dominates every unselected one."""
    x = jnp.asarray(vals, jnp.float32)
    k = min(k, x.shape[0])
    score = jnp.abs(x)
    m = np.asarray(exact_topk_mask(score, k))
    n_live = int((np.asarray(score) > 0).sum())
    assert int(m.sum()) == min(k, n_live)
    assert int(m.sum()) <= k
    assert not np.any(np.asarray(score)[m > 0] == 0.0)
    # every selected score >= every unselected score
    sel = np.asarray(score)[m > 0]
    unsel = np.asarray(score)[m == 0]
    if len(sel) and len(unsel):
        assert sel.min() >= unsel.max() - 1e-6


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.floats(0, 1e3, allow_nan=False, width=32), min_size=4, max_size=128
    ),
    st.integers(1, 128),
)
def test_threshold_topk_superset_of_k(vals, k):
    score = jnp.asarray(vals, jnp.float32)
    k = min(k, score.shape[0])
    m = np.asarray(threshold_topk_mask(score, k, n_iters=30))
    # bisection invariant: at least min(k, #positive) selected (zero scores
    # carry no gradient and are never selected — see the zero-round test),
    # and the selected set contains the exact positive top-k (threshold <=
    # k-th largest value)
    n_pos = int((np.asarray(score) > 0).sum())
    assert int(m.sum()) >= min(k, n_pos)
    assert not np.any(np.asarray(score)[m > 0] == 0.0)
    # threshold <= k-th largest (meaningful only when k positives exist)
    if n_pos >= k:
        kth = np.sort(np.asarray(score))[-k]
        assert np.asarray(score)[m > 0].min() <= kth + 1e-6
    # cardinality stays at k whenever the bisection can separate the k-th
    # and (k+1)-th scores (ties / sub-resolution gaps legitimately exceed
    # k, so only assert when the gap clears the bisection's resolution)
    # (the bisection runs in float32, so its resolution bottoms out near
    # the f32 ulp of max(score) — demand a comfortably larger gap)
    s = np.sort(np.asarray(score))[::-1]
    if n_pos >= k and (len(s) == k or s[k - 1] - s[k] > s[0] * 2.0**-18):
        assert int(m.sum()) == k


def test_threshold_topk_zero_gradient_round():
    """Regression: an all-zero score collapsed the bisection to tau = 0 and
    ``score >= 0`` selected *every* coordinate — a zero gradient round
    would ship the whole (zero) vector. Cardinality must stay
    <= max(k, ties): here 0, and min(k, #positive) when a few coordinates
    are live."""
    assert float(threshold_topk_mask(jnp.zeros(64), 8).sum()) == 0.0
    # fewer positives than k: select exactly the positives, nothing else
    score = jnp.zeros(64).at[jnp.array([3, 17])].set(jnp.array([2.0, 5.0]))
    m = np.asarray(threshold_topk_mask(score, 8))
    np.testing.assert_array_equal(np.nonzero(m)[0], [3, 17])


def test_threshold_matches_exact_when_distinct():
    score = jnp.array([5.0, 1.0, 4.0, 2.0, 3.0])
    m_t = threshold_topk_mask(score, 2, n_iters=40)
    m_e = exact_topk_mask(score, 2)
    np.testing.assert_array_equal(m_t, m_e)


def test_fixed_k_payload_roundtrip():
    vals = jnp.array([1.0, -9.0, 3.0, 0.5])
    score = jnp.abs(vals)
    pv, pi = fixed_k_payload(score, vals, 2)
    dense = scatter_add_payloads(pv[None], pi[None], jnp.ones(1), 4)
    np.testing.assert_allclose(dense, [0, -9.0, 3.0, 0])


def test_mask_to_payload_pads_with_noops():
    vals = jnp.array([1.0, -9.0, 3.0, 0.5])
    mask = jnp.array([0.0, 1.0, 0.0, 0.0])  # cardinality 1 < k=3
    pv, pi = mask_to_payload(mask, vals, 3)
    dense = scatter_add_payloads(pv[None], pi[None], jnp.ones(1), 4)
    np.testing.assert_allclose(dense, [0, -9.0, 0, 0])


def test_sparsity_to_k():
    assert sparsity_to_k(100, 0.01) == 1
    assert sparsity_to_k(100, 0.015) == 2
    assert sparsity_to_k(100, 1.0) == 100
    assert sparsity_to_k(100, 0.0) == 1  # floor at 1
    assert sparsity_to_k(10, 0.5) == 5


def test_sparsity_to_k_float_ceil_regression():
    """S * J computed in binary floating point lands ulps above the exact
    integer product (0.07 * 100 == 7.000000000000001); a naive ceil then
    inflates k — and with it the paper's compression ratio S = k/J
    (regression: sparsity_to_k(100, 0.07) returned 8)."""
    assert sparsity_to_k(100, 0.07) == 7
    # exhaustive S x J sweep over the paper's grid + decimal fractions:
    # k must equal the exact ceil of the rational product
    import fractions

    grid_S = (0.1, 0.01, 0.001, 0.07, 0.02, 0.05, 0.2, 0.5, 0.3)
    grid_J = (10, 100, 1000, 4096, 65536, 100_000)
    for S in grid_S:
        frac = fractions.Fraction(str(S))
        for J in grid_J:
            exact = max(1, min(J, -((-frac * J) // 1)))
            assert sparsity_to_k(J, S) == exact, (S, J)


@settings(max_examples=60, deadline=None)
@given(
    st.integers(1, 100_000),
    st.floats(0.0, 1.0, allow_nan=False),
    st.integers(1, 100_000),
    st.floats(0.0, 1.0, allow_nan=False),
)
def test_sparsity_to_k_monotone_in_both_arguments(J1, S1, J2, S2):
    """k = ceil(S*J) clipped to [1, J] is monotone in the sparsity at
    fixed length and in the length at fixed sparsity (ISSUE 4 satellite —
    property net over the PR-2 epsilon-tolerant ceil)."""
    lo_S, hi_S = sorted((S1, S2))
    assert sparsity_to_k(J1, lo_S) <= sparsity_to_k(J1, hi_S)
    lo_J, hi_J = sorted((J1, J2))
    assert sparsity_to_k(lo_J, S1) <= sparsity_to_k(hi_J, S1)
    # range invariant
    k = sparsity_to_k(J1, S1)
    assert 1 <= k <= J1


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 50_000), st.integers(1, 50_000))
def test_sparsity_to_k_exact_on_representable_products(J, k0):
    """For S computed as k0/J (the only way real configs produce nominally
    integer products), the epsilon-tolerant ceil must recover exactly k0 —
    never the k0+1 a naive ceil gives when float rounding lands S*J a few
    ulps above the integer."""
    k0 = min(k0, J)
    S = k0 / J
    assert sparsity_to_k(J, S) == k0


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.floats(0, 1e3, allow_nan=False, width=32), min_size=4, max_size=64
    ),
    st.integers(1, 64),
)
def test_all_selectors_never_select_zero_scores(vals, k):
    """Cross-selector invariant (regression net for the PR-2 zero-score
    fixes): no registered selector ever selects a zero-score coordinate,
    and the exact selector never exceeds cardinality k."""
    from repro.core.selectors import SELECTORS

    score = jnp.asarray(vals, jnp.float32)
    k = min(k, score.shape[0])
    for name, select in SELECTORS.items():
        m = np.asarray(select(score, k))
        assert set(np.unique(m)) <= {0.0, 1.0}, name
        assert not np.any(np.asarray(score)[m > 0] == 0.0), name
        if name == "exact":
            assert int(m.sum()) <= k


def test_sparsity_to_k_shifts_leaf_plan_and_wire_bytes():
    """The off-by-one propagated into LeafPlan.k and the byte accounting:
    at S=0.07, J=100 each coo_fp32 payload is 8 B/coordinate — one
    phantom coordinate per leaf per gather hop."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import (
        DistConfig,
        build_plan,
        comm_round_bytes,
    )

    class _Mesh:
        shape: ClassVar[dict] = {"data": 4}

    shapes = {"w": jax.ShapeDtypeStruct((100,), jnp.float32)}
    plan = build_plan(shapes, {"w": P(None)}, _Mesh(), 0.07)
    assert plan["w"].k == 7
    dist = DistConfig(codec="coo_fp32", collective="sparse_allgather")
    pred, meas = comm_round_bytes(plan, dist, _Mesh())
    # (N-1) gather hops x k coordinates x 8 B — not k=8's 192 B
    assert pred == meas == 3 * 7 * 8


# ---------------------------------------------------------------------------
# sparsifier algebra (paper Algorithm 1 / 2 invariants)
# ---------------------------------------------------------------------------
def _step(kind, g, state=None, g_prev=None, **kw):
    cfg = SparsifierConfig(kind=kind, **kw)
    sp = make_sparsifier(cfg)
    if state is None:
        state = sp.init(g.shape[0])
    if g_prev is None:
        g_prev = jnp.zeros_like(g)
    return sp, sp.step(state, g, g_prev)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.floats(-100, 100, allow_nan=False, width=32), min_size=4, max_size=64
    ),
    st.floats(0.05, 1.0),
)
def test_error_conservation(vals, S):
    """eps' + ghat == a == eps + g  (Alg. 1 lines 3/6; Alg. 2 lines 7/12)."""
    g = jnp.asarray(vals, jnp.float32)
    for kind in ("topk", "regtopk", "hard_threshold"):
        sp, (ghat, mask, ns) = _step(kind, g, sparsity=S, threshold=1.0)
        np.testing.assert_allclose(ns.eps + ghat, g, rtol=1e-6, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.floats(-100, 100, allow_nan=False, width=32), min_size=4, max_size=64
    )
)
def test_mask_cardinality_topk(vals):
    g = jnp.asarray(vals, jnp.float32)
    k = sparsity_to_k(g.shape[0], 0.25)
    n_live = int((np.abs(np.asarray(g)) > 0).sum())
    sp, (ghat, mask, ns) = _step("topk", g, sparsity=0.25)
    assert int(np.asarray(mask).sum()) == min(k, n_live)
    assert int((np.asarray(ghat) != 0).sum()) <= k


def test_round0_regtopk_equals_topk():
    """Alg. 2 line 2: round 0 of RegTop-k is plain Top-k."""
    g = jnp.array([3.0, -1.0, 0.5, -7.0, 2.0])
    _, (gh_t, m_t, _) = _step("topk", g, sparsity=0.4)
    _, (gh_r, m_r, _) = _step("regtopk", g, sparsity=0.4, mu=1.0)
    np.testing.assert_array_equal(m_t, m_r)
    np.testing.assert_allclose(gh_t, gh_r)


def test_mu_to_zero_recovers_topk_after_round0():
    """Sec. 4 case (1): mu -> 0 makes the regularizer -> 1 (Top-k)."""
    key = jax.random.PRNGKey(0)
    g0 = jax.random.normal(key, (32,))
    g1 = jax.random.normal(jax.random.fold_in(key, 1), (32,))
    g_agg = 0.5 * g0  # arbitrary broadcast value

    def run(kind, mu):
        cfg = SparsifierConfig(kind=kind, sparsity=0.25, mu=mu, omega=1.0)
        sp = make_sparsifier(cfg)
        st_ = sp.init(32)
        _, _, st_ = sp.step(st_, g0, jnp.zeros(32))
        ghat, mask, _ = sp.step(st_, g1, g_agg)
        return np.asarray(mask)

    np.testing.assert_array_equal(run("regtopk", 1e-9), run("topk", 1e9))


def test_regtopk_damps_cancelling_entry():
    """Sec. 4 case (2): if entries cancel at the server, Delta = -1 and the
    coordinate is damped to ~0 score -> never selected next round."""
    # worker sees a large first coordinate that cancelled: g_agg_prev[0] = 0
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0, omega=0.5)
    sp = make_sparsifier(cfg)
    state = sp.init(2)
    g0 = jnp.array([100.0, 1.0])
    ghat, mask, state = sp.step(state, g0, jnp.zeros(2))  # round0: picks idx0
    np.testing.assert_array_equal(mask, [1.0, 0.0])
    g_agg = jnp.array([0.0, 0.0])  # the big entry cancelled at the server
    g1 = jnp.array([100.0, 1.0])
    ghat, mask, state = sp.step(state, g1, g_agg)
    # accumulated a = [100, 2]; Delta[0] = (0 - .5*100)/(.5*100) = -1
    # -> score[0] = 100 * tanh(0) = 0 < score[1] -> picks idx1
    np.testing.assert_array_equal(mask, [0.0, 1.0])


def test_posterior_distortion_formula():
    """Check Delta against Alg. 2 line 8 by hand."""
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=2.0, omega=0.25)
    sp = make_sparsifier(cfg)
    state = sp.init(4)
    g0 = jnp.array([4.0, -3.0, 2.0, 1.0])
    _, m0, state = sp.step(state, g0, jnp.zeros(4))  # selects idx 0,1
    g_agg = jnp.array([2.0, -1.0, 0.3, 0.2])
    g1 = jnp.array([1.0, 1.0, 1.0, 1.0])
    a1 = state.eps + g1  # = [0,0,2,1] + [1,1,1,1] = [1,1,3,2]
    np.testing.assert_allclose(a1, [1.0, 1.0, 3.0, 2.0])
    # Delta_sent = (g_agg - w*a_prev)/(w*a1), sent = {0,1}
    d0 = (2.0 - 0.25 * 4.0) / (0.25 * 1.0)  # = 4
    d1 = (-1.0 - 0.25 * -3.0) / (0.25 * 1.0)  # = -1
    score_expected = np.abs(np.asarray(a1)) * np.tanh(
        np.abs(1 + np.array([d0, d1, cfg.q_const, cfg.q_const])) / 2.0
    )
    score = np.asarray(sp._score(state, a1, g_agg))
    np.testing.assert_allclose(score, score_expected, rtol=1e-6)


def test_hard_threshold_variable_k():
    g = jnp.array([0.5, 2.0, -3.0, 0.1])
    _, (ghat, mask, _) = _step("hard_threshold", g, threshold=1.0)
    np.testing.assert_array_equal(mask, [0, 1, 1, 0])


def test_none_sparsifier_identity():
    g = jnp.array([1.0, -2.0, 3.0])
    _, (ghat, mask, ns) = _step("none", g)
    np.testing.assert_allclose(ghat, g)
    np.testing.assert_allclose(ns.eps, 0.0)


def test_zero_accumulated_gradient_no_nan():
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0)
    sp = make_sparsifier(cfg)
    state = sp.init(4)
    _, _, state = sp.step(state, jnp.zeros(4), jnp.zeros(4))
    ghat, mask, state = sp.step(state, jnp.zeros(4), jnp.zeros(4))
    assert not np.any(np.isnan(np.asarray(ghat)))
    assert not np.any(np.isnan(np.asarray(state.eps)))


def test_y_exponent_changes_ranking():
    """Remark 4: y < 1 flattens the prior; ranking can change."""
    cfg1 = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0, y=1.0)
    cfg2 = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0, y=0.1)
    sp1, sp2 = make_sparsifier(cfg1), make_sparsifier(cfg2)
    a = jnp.array([10.0, 1.0])
    st1 = sp1.init(2)._replace(  # reprolint: disable=RPL106 (test setup)
        s_prev=jnp.array([1.0, 1.0]),
        a_prev=jnp.array([10.0, 1.0]),
        t=jnp.ones((), jnp.int32),
    )
    g_prev = jnp.array([1.0, 1.2])  # idx0 mostly cancelled, idx1 reinforced
    s1 = np.asarray(sp1._score(st1, a, g_prev))
    s2 = np.asarray(sp2._score(st1, a, g_prev))
    # with y=0.1 the regularizer dominates -> ranking flips toward idx1
    assert (s1[0] > s1[1]) != (s2[0] > s2[1]) or s2[1] > s2[0]


# ---------------------------------------------------------------------------
# aggregation equivalence
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dense_vs_sparse_aggregation_equivalence(seed):
    key = jax.random.PRNGKey(seed)
    N, L, k = 4, 32, 8
    ghat = jax.random.normal(key, (N, L))
    # sparsify each row to exactly k nonzeros
    masks = jax.vmap(lambda r: exact_topk_mask(jnp.abs(r), k))(ghat)
    ghat = ghat * masks
    w = jnp.full((N,), 1.0 / N)
    dense = dense_mean(ghat, w)
    vals, idx = jax.vmap(lambda m, v: mask_to_payload(m, v, k))(masks, ghat)
    sparse = scatter_add_payloads(vals, idx, w, L)
    np.testing.assert_allclose(dense, sparse, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end simulator behaviour (paper Fig. 1 toy, exact numbers)
# ---------------------------------------------------------------------------
def _toy_sim(kind, mu=1.0, steps=60):
    x = jnp.array([[100.0, 1.0], [-100.0, 1.0]])

    def grad_fn(theta, n):
        xn = x[n]
        e = jnp.exp(-jnp.dot(theta, xn))
        return -e * xn / (1 + e)

    def loss(theta):
        return jnp.mean(jnp.log(1 + jnp.exp(-x @ theta)))

    cfg = SparsifierConfig(kind=kind, sparsity=0.5, mu=mu)
    sim = DistributedSim(
        grad_fn, n_workers=2, length=2, sparsifier_cfg=cfg, learning_rate=0.9
    )
    fin, trace = sim.run(jnp.array([0.0, 1.0]), steps, trace_fn=loss)
    return np.asarray(trace)


def test_fig1_topk_stuck_regtopk_tracks():
    """Paper Fig. 1: Top-1 makes no progress for ~50 iters; RegTop-1 tracks
    centralized training."""
    t_topk = _toy_sim("topk")
    t_reg = _toy_sim("regtopk")
    t_none = _toy_sim("none")
    assert t_topk[49] == pytest.approx(t_topk[0])  # stuck
    assert t_reg[49] < 0.05  # converging
    assert abs(t_reg[49] - t_none[49]) < 0.01  # tracks ideal


def test_simulator_sparse_aggregation_matches_dense():
    x = jnp.array([[100.0, 1.0], [-100.0, 1.0]])

    def grad_fn(theta, n):
        xn = x[n]
        e = jnp.exp(-jnp.dot(theta, xn))
        return -e * xn / (1 + e)

    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0)
    out = {}
    for agg in ("dense_allreduce", "sparse_allgather"):
        sim = DistributedSim(
            grad_fn, 2, 2, cfg, learning_rate=0.9, aggregation=agg
        )
        fin, _ = sim.run(jnp.array([0.0, 1.0]), 30)
        out[agg] = np.asarray(fin.theta)
    np.testing.assert_allclose(
        out["dense_allreduce"], out["sparse_allgather"], rtol=1e-5
    )


def test_training_equivalence_dense_vs_fused_fastpath():
    """ISSUE 5: a full training run with the fused Pallas fastpath must
    track the dense path exactly. The simulator fuses the scoring stage
    (SparsifierConfig.score_fn → the regtopk score kernel, interpret mode
    on CPU); the score kernel replays the same f32 op chain, so the
    trajectories match to float tolerance — and selection (discrete)
    never diverges."""
    from repro.data.pipeline import linreg_grad_fn, make_linreg

    data = make_linreg(3, 4, 64, 100)
    grad_fn = linreg_grad_fn(data)
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.1, mu=1.0)
    out = {}
    for fp in ("off", "on"):
        sim = DistributedSim(
            grad_fn, 4, 64, cfg, learning_rate=1e-2, fastpath=fp
        )
        assert (sim.sparsifier.cfg.score_fn is not None) == (fp == "on")
        fin, _ = sim.run(jnp.zeros(64), 40)
        out[fp] = np.asarray(fin.theta)
    np.testing.assert_allclose(out["off"], out["on"], rtol=1e-6, atol=1e-7)


def test_sim_fastpath_auto_declines_off_tpu():
    """'auto' must resolve to the unfused path off-TPU (interpret-mode
    Pallas never beats XLA), leaving score_fn unset; unknown modes raise."""
    from repro.data.pipeline import linreg_grad_fn, make_linreg

    grad_fn = linreg_grad_fn(make_linreg(3, 2, 16, 50))
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25)
    sim = DistributedSim(grad_fn, 2, 16, cfg, fastpath="auto")
    if jax.default_backend() != "tpu":
        assert sim.sparsifier.cfg.score_fn is None
    with pytest.raises(ValueError, match="fastpath"):
        DistributedSim(grad_fn, 2, 16, cfg, fastpath="bogus")


def test_dgc_momentum_correction():
    """DGC: velocity conservation + momentum masking (Lin et al. [26])."""
    cfg = SparsifierConfig(kind="dgc", sparsity=0.5)
    sp = make_sparsifier(cfg)
    state = sp.init(4)
    g = jnp.array([4.0, -3.0, 1.0, 0.5])
    ghat, mask, s1 = sp.step(state, g, jnp.zeros(4))
    # round 0: u = g, v = g -> top-2 = idx 0,1
    np.testing.assert_array_equal(mask, [1, 1, 0, 0])
    np.testing.assert_allclose(s1.eps + ghat, g)  # v conserved
    # momentum zeroed where sent
    np.testing.assert_allclose(np.asarray(s1.a_prev)[:2], 0.0)
    np.testing.assert_allclose(np.asarray(s1.a_prev)[2:], [1.0, 0.5])
    # round 1: u = 0.9*u_prev + g
    g2 = jnp.array([0.0, 0.0, 1.0, 0.0])
    ghat2, mask2, s2 = sp.step(s1, g2, jnp.zeros(4))
    # v = eps + u = [0,0,1,0.5] + [0,0,1.9,0.45] = [0,0,2.9,0.95]
    np.testing.assert_allclose(np.asarray(ghat2), [0, 0, 2.9, 0.95], rtol=1e-6)


def test_dgc_toy_example_progresses():
    t = _toy_sim("dgc")
    assert np.isfinite(t).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
def test_coordinated_kinds_produce_identical_masks(seed, n_workers):
    """coordtopk/cyclic invariant: given identical common inputs (broadcast
    aggregate + synchronized state), every worker selects the same mask
    regardless of its private gradient."""
    key = jax.random.PRNGKey(seed)
    L, S = 24, 0.25
    grads = jax.random.normal(key, (n_workers, L))  # heterogeneous
    g_prev = jax.random.normal(jax.random.fold_in(key, 1), (L,))
    for kind in ("coordtopk",):
        cfg = SparsifierConfig(kind=kind, sparsity=S)
        sp = make_sparsifier(cfg)
        st_ = sp.init(L)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_workers,) + x.shape), st_
        )
        for _ in range(3):
            ghat, masks, stacked = jax.vmap(
                sp.step, in_axes=(0, 0, None)
            )(stacked, grads, g_prev)
            m = np.asarray(masks)
            assert (m == m[0]).all(), f"{kind}: masks diverged"


def test_coordtopk_linreg_converges_where_topk_plateaus():
    """The §Beyond headline in miniature: S=0.3, N=8 heterogeneous linreg."""
    from repro.data.pipeline import linreg_grad_fn, make_linreg

    data = make_linreg(5, 8, 32, 100)
    grad_fn = linreg_grad_fn(data)
    out = {}
    for kind in ("topk", "coordtopk"):
        cfg = SparsifierConfig(kind=kind, sparsity=0.3)
        sim = DistributedSim(grad_fn, 8, 32, cfg, learning_rate=1e-2)
        _, tr = sim.run(
            jnp.zeros(32), 3000,
            trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
        )
        out[kind] = float(np.asarray(tr)[-1])
    assert out["coordtopk"] < 1e-4
    assert out["topk"] > 10 * out["coordtopk"]
