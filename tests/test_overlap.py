"""Bucketed overlap scheduler tests (ISSUE 10).

Property tests (hypothesis, or the ``_hyp`` fallback shim) over the
bin-pack + timeline math, spec/validation gates, and the off-switch
guarantee: in-process bucketed aggregation must be bit-for-bit identical
to ``overlap="off"`` across codecs — the schedule may only *reorder* the
independent per-leaf rounds. The real 8-device differential lives in
``tests/test_distributed.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import comm
from repro.comm.overlap import (
    Bucket,
    LeafCost,
    OverlapConfig,
    bucketize,
    overlap_timeline,
    parse_overlap,
)

# ---------------------------------------------------------------------------
# spec / config gates
# ---------------------------------------------------------------------------


def test_parse_overlap_grammar():
    assert parse_overlap("off") is None
    assert parse_overlap(" off ") is None
    assert parse_overlap("buckets:1").n_buckets == 1
    assert parse_overlap("buckets:16").n_buckets == 16
    with pytest.raises(ValueError, match="n_buckets"):
        parse_overlap("buckets:0")
    with pytest.raises(ValueError, match="not an int"):
        parse_overlap("buckets:x")
    with pytest.raises(ValueError, match="unknown overlap spec"):
        parse_overlap("stream")


def test_overlap_config_validation():
    with pytest.raises(ValueError, match="balance_factor"):
        OverlapConfig(balance_factor=0.5)
    with pytest.raises(ValueError, match="min_bucket_bytes"):
        OverlapConfig(min_bucket_bytes=-1)
    with pytest.raises(ValueError, match="max_bucket_bytes"):
        OverlapConfig(min_bucket_bytes=100, max_bucket_bytes=50)


def test_bucketize_input_validation():
    with pytest.raises(ValueError, match="at least one leaf"):
        bucketize([])
    mixed = [LeafCost(1, (1.0,)), LeafCost(1, (1.0, 2.0))]
    with pytest.raises(ValueError, match="same dp axes"):
        bucketize(mixed)


def test_timeline_compute_seconds_validation():
    plan = bucketize([LeafCost(10, (1e-3,))])
    with pytest.raises(ValueError, match="1 buckets"):
        overlap_timeline(plan, [0.1, 0.2])
    with pytest.raises(ValueError, match="non-negative"):
        overlap_timeline(plan, [-1.0])


# ---------------------------------------------------------------------------
# bin-pack + timeline properties
# ---------------------------------------------------------------------------

_costs_strategy = st.lists(
    st.floats(min_value=1e-6, max_value=1.0), min_size=1, max_size=24
)


def _as_costs(seconds, n_axes=2):
    # split each leaf's seconds across axes deterministically (60/40)
    out = []
    for i, s in enumerate(seconds):
        ax = (
            (0.6 * s, 0.4 * s) if n_axes == 2 else (s,)
        )
        out.append(LeafCost(int(1e4 * s) + 1, ax, ("c", "h")))
    return out


@settings(max_examples=40, deadline=None)
@given(_costs_strategy, st.integers(min_value=1, max_value=8))
def test_bucketize_partitions_exactly(seconds, n_buckets):
    costs = _as_costs(seconds)
    plan = bucketize(costs, OverlapConfig(n_buckets=n_buckets))
    order = sorted(plan.leaf_order())
    assert order == list(range(len(costs)))
    assert plan.n_leaves == len(costs)
    # buckets launch in ascending smallest-leaf order
    firsts = [min(b.leaves) for b in plan.buckets]
    assert firsts == sorted(firsts)


@settings(max_examples=40, deadline=None)
@given(_costs_strategy, st.integers(min_value=1, max_value=8))
def test_bucketize_balance_bound(seconds, n_buckets):
    costs = _as_costs(seconds)
    cfg = OverlapConfig(n_buckets=n_buckets)
    plan = bucketize(costs, cfg)
    total = sum(c.seconds for c in costs)
    max_leaf = max(c.seconds for c in costs)
    ideal = max(total / plan.n_buckets, max_leaf)
    assert (
        plan.n_buckets == 1
        or max(b.seconds for b in plan.buckets)
        <= cfg.balance_factor * ideal + 1e-6
    )


@settings(max_examples=40, deadline=None)
@given(_costs_strategy, st.integers(min_value=1, max_value=8))
def test_timeline_never_exceeds_sync(seconds, n_buckets):
    costs = _as_costs(seconds)
    plan = bucketize(costs, OverlapConfig(n_buckets=n_buckets))
    tl = overlap_timeline(plan)
    assert tl.seconds <= tl.sync_seconds + 1e-12
    # stamps are monotone and self-consistent
    assert all(
        lo <= mid <= hi
        for lo, mid, hi in zip(tl.launch, tl.intra_done, tl.complete)
    )


@settings(max_examples=20, deadline=None)
@given(_costs_strategy)
def test_timeline_single_bucket_equals_sync(seconds):
    plan = bucketize(_as_costs(seconds), OverlapConfig(n_buckets=1))
    tl = overlap_timeline(plan)
    assert tl.seconds == tl.sync_seconds


def test_timeline_strict_win_on_slow_outer_topo():
    """Two equal buckets with a dominant inter stage: bucket 1's intra
    work hides behind bucket 0's inter drain — strictly faster."""
    costs = [LeafCost(100, (2e-3, 1e-3)), LeafCost(100, (2e-3, 1e-3))]
    tl = overlap_timeline(bucketize(costs, OverlapConfig(n_buckets=2)))
    assert tl.seconds < tl.sync_seconds
    # exactly one intra stage (1ms) is hidden
    assert np.isclose(tl.sync_seconds - tl.seconds, 1e-3)


def test_bucket_stage_split():
    b = Bucket(
        leaves=(0,), seconds=3.0, bytes_on_wire=1,
        axis_seconds=(2.0, 1.0),
    )
    assert b.inter_seconds == 2.0
    assert b.intra_seconds == 1.0


def test_min_bucket_bytes_merges():
    costs = [LeafCost(10, (1e-3,)) for _ in range(6)]
    plan = bucketize(
        costs, OverlapConfig(n_buckets=3, min_bucket_bytes=1000)
    )
    assert plan.n_buckets == 1
    assert sorted(plan.leaf_order()) == list(range(6))


def test_max_bucket_bytes_steers():
    costs = [LeafCost(100, (1e-3,)) for _ in range(4)]
    plan = bucketize(
        costs, OverlapConfig(n_buckets=4, max_bucket_bytes=100)
    )
    assert plan.n_buckets == 4
    assert all(b.bytes_on_wire == 100 for b in plan.buckets)


# ---------------------------------------------------------------------------
# leaf_cost / planner integration
# ---------------------------------------------------------------------------


def test_leaf_cost_matches_predict():
    topo = comm.LinkTopo(
        (comm.AlphaBeta(1e-5, 1e-9), comm.AlphaBeta(1e-6, 1e-10))
    )
    lc = comm.leaf_cost(
        "coo_fp32", "hierarchical", 1 << 16, 1 << 10, (2, 4), topo
    )
    est = comm.predict(
        "coo_fp32", "hierarchical", 1 << 16, 1 << 10, (2, 4), topo
    )
    assert lc.bytes_on_wire == est.bytes_on_wire
    assert np.isclose(lc.seconds, est.seconds, rtol=1e-12)
    assert len(lc.axis_seconds) == 2
    assert lc.wire == ("coo_fp32", "hierarchical")


def test_plan_tree_overlap_schedule():
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import LeafPlan

    tree = {
        "a": LeafPlan((1 << 16,), (1 << 16,), 1 << 16, 1 << 10, P(None)),
        "b": LeafPlan((1 << 14,), (1 << 14,), 1 << 14, 1 << 8, P(None)),
        "c": LeafPlan((256,), (256,), 256, 8, P(None)),
    }
    topo = comm.LinkTopo(
        (comm.AlphaBeta(1e-4, 1e-8), comm.AlphaBeta(1e-5, 1e-9))
    )
    cp = comm.plan_tree(tree, (2, 4), topo)
    assert cp.buckets is None and cp.timeline is None
    cp2 = comm.plan_tree(
        tree, (2, 4), topo,
        collectives=["hierarchical"],
        overlap=OverlapConfig(n_buckets=2),
    )
    assert cp2.buckets.n_buckets == 2
    assert sorted(cp2.buckets.leaf_order()) == [0, 1, 2]
    assert cp2.timeline.seconds < cp2.total_seconds
    cp1 = comm.plan_tree(
        tree, (2, 4), topo,
        collectives=["hierarchical"],
        overlap=OverlapConfig(n_buckets=1),
    )
    assert np.isclose(cp1.timeline.seconds, cp1.total_seconds, rtol=1e-9)


# ---------------------------------------------------------------------------
# distributed runtime: off-switch bit-for-bit + timeline metric
# ---------------------------------------------------------------------------


def _micro_train(overlap, codec, steps=2, monkey_costs=None, monkeypatch=None):
    from repro.compat import make_mesh
    from repro.core import distributed as D
    from repro.core.sparsify import SparsifierConfig
    from repro.data import TokenPipeline
    from repro.models import ModelConfig, get_family
    from repro.optim import OptConfig, make_optimizer

    if monkey_costs is not None:
        monkeypatch.setattr(D, "_leaf_overlap_costs", monkey_costs)
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=128, remat=False,
    )
    mod = get_family(cfg)
    dist = D.DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.05, mu=1.0),
        optimizer=OptConfig(kind="adam", learning_rate=3e-3),
        aggregation="sparse_allgather", dp_axes=("data",),
        codec=codec, overlap=overlap,
    )
    asm = D.assemble(mod, cfg, dist, mesh)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(dist.optimizer)
    opt_state = opt.init(params)
    sp_state, _ = D.init_sparsifier_state(
        asm.plan, 1, mesh, ("data",), jnp.float32
    )
    pipe = TokenPipeline(cfg, global_batch=4, seq=16)
    step = jax.jit(asm.train_step)
    with mesh:
        for t in range(steps):
            params, opt_state, sp_state, m = step(
                params, opt_state, sp_state, pipe.batch_at(t)
            )
    return params, m


def _synthetic_costs(plan, dist, mesh):
    """Nonzero heterogeneous fake costs: on the single-device test mesh
    every real leaf cost is zero (no wire), which collapses the schedule
    to one bucket — these force a genuine multi-bucket reorder so the
    bit-for-bit property is tested against a *permuted* leaf order."""
    from repro.core.distributed import _is_plan

    leaves = jax.tree.leaves(plan, is_leaf=_is_plan)
    n = len(leaves)
    return [
        LeafCost(100 * (i + 1), (float(n - i), 1.0), ("c", "h"))
        for i in range(n)
    ]


@pytest.mark.parametrize("codec", ["coo_fp32", "coo_idx_delta", "coo_q8"])
def test_bucketed_aggregation_bitforbit(codec, monkeypatch):
    p_off, m_off = _micro_train("off", codec)
    p_on, m_on = _micro_train(
        "buckets:3", codec,
        monkey_costs=_synthetic_costs, monkeypatch=monkeypatch,
    )
    for a, b in zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "timeline" not in m_off
    tl = np.asarray(m_on["timeline"])
    assert tl.shape == (3, 2)
    # launch <= complete per bucket, completes monotone
    assert (tl[:, 0] <= tl[:, 1]).all()
    assert (np.diff(tl[:, 1]) >= 0).all()


def test_comm_round_timeline_gates():
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh
    from repro.core import distributed as D
    from repro.core.sparsify import SparsifierConfig

    mesh = make_mesh((1, 1), ("data", "model"))
    plan = {"w": D.LeafPlan((64,), (64,), 64, 4, P(None))}
    base = dict(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.05, mu=1.0),
        aggregation="sparse_allgather", dp_axes=("data",),
    )
    off = D.DistConfig(**base)
    assert off.resolved_overlap() is None
    with pytest.raises(ValueError, match="overlap != 'off'"):
        D.comm_round_timeline(plan, off, mesh)
    on = D.DistConfig(overlap="buckets:2", **base)
    bplan, tl = D.comm_round_timeline(plan, on, mesh)
    assert bplan.n_leaves == 1
    assert tl.seconds <= tl.sync_seconds + 1e-12
    with pytest.raises(ValueError, match="unknown overlap spec"):
        D.DistConfig(overlap="bogus", **base).resolved_overlap()


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------


def _sim(**kw):
    from repro.core.simulator import DistributedSim
    from repro.core.sparsify import SparsifierConfig

    def gf(theta, w):
        return theta + jnp.asarray(w, theta.dtype)

    return DistributedSim(
        gf, 8, 2048,
        SparsifierConfig(kind="regtopk", sparsity=0.02, mu=1.0),
        codec="coo_fp32", collective="hierarchical", dp_shape=(2, 4),
        link_topo=comm.LinkTopo(
            (comm.AlphaBeta(1e-5, 1e-9), comm.AlphaBeta(1e-6, 1e-10))
        ),
        **kw,
    )


def test_sim_overlap_bitforbit_and_timeline():
    theta0 = jnp.zeros(2048)
    _, tr_off = _sim().run(theta0, 4)
    s_on = _sim(overlap="buckets:4")
    _, tr_on = s_on.run(theta0, 4)
    np.testing.assert_array_equal(np.asarray(tr_off), np.asarray(tr_on))
    bplan, tl = s_on.round_timeline()
    # single leaf -> the schedule clamps to one bucket; pricing matches
    # the synchronous wire estimate
    assert bplan.n_buckets == 1
    assert np.isclose(
        tl.sync_seconds, s_on.wire_bytes_per_round().seconds, rtol=1e-9
    )
    assert np.isclose(tl.seconds, tl.sync_seconds, rtol=1e-9)


def test_sim_overlap_gates():
    with pytest.raises(ValueError, match="unknown overlap spec"):
        _sim(overlap="stream")
    with pytest.raises(ValueError, match="overlap != 'off'"):
        _sim().round_timeline()
