"""Per-architecture smoke tests (reduced variants) + decode parity.

Every assigned arch instantiates a REDUCED variant of the same family
(<=2 layers equivalent, d_model <= 512, <= 4 experts) and runs one forward
/ train step on CPU asserting output shapes + no NaNs; decoders also run a
cache step. Teacher-forcing parity checks decode-with-cache against the
full forward pass for each cache implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import get_family, make_batch

ARCHS = sorted(cfglib.ARCHS)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = cfglib.get_config(arch).smoke_variant()
    mod = get_family(cfg)
    params, axes = mod.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 16)
    loss, metrics = jax.jit(lambda p, b: mod.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # one SGD step decreases nothing necessarily, but must stay finite
    g = jax.grad(lambda p: mod.loss_fn(p, cfg, batch)[0])(params)
    newp = jax.tree.map(lambda p_, g_: p_ - 0.01 * g_, params, g)
    loss2, _ = mod.loss_fn(newp, cfg, batch)
    assert np.isfinite(float(loss2)), f"{arch}: non-finite post-step loss"
    # logits shape via prefill
    logits = mod.prefill(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = cfglib.get_config(arch).smoke_variant()
    mod = get_family(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    cache = mod.init_cache(cfg, 2, 32)
    tokens = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: mod.decode_step(p, cfg, c, t)
    )(params, cache, tokens)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert int(cache2["pos"]) == 1
    # cache axes tree matches cache structure
    ax = mod.cache_axes(cfg)
    jax.tree.map(lambda *_: None, cache, ax,
                 is_leaf=lambda x: isinstance(x, tuple))


def _decode_all(mod, cfg, params, batch, T, cache_extra=None):
    cache = mod.init_cache(cfg, batch["tokens"].shape[0], T)
    if cache_extra:
        cache.update(cache_extra)
    outs = []
    step = jax.jit(lambda p, c, t: mod.decode_step(p, cfg, c, t))
    for t in range(T):
        logits, cache = step(params, cache, batch["tokens"][:, t : t + 1])
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize(
    "arch", ["qwen2.5-3b", "granite-3-8b-swa", "mamba2-780m", "zamba2-7b",
             "whisper-tiny", "mixtral-8x7b"]
)
def test_decode_matches_forward(arch):
    """Teacher-forcing parity: cached decode == full forward logits."""
    cfg = cfglib.get_config(arch).smoke_variant().replace(
        remat=False, capacity_factor=8.0  # dropless forward for parity
    )
    mod = get_family(cfg)
    params, _ = mod.init(jax.random.PRNGKey(1), cfg)
    T = 12
    batch = make_batch(cfg, 2, T, key=jax.random.PRNGKey(3))
    if cfg.family == "encdec":
        full, _ = mod.forward(params, cfg, batch)
        ck, cv = mod.build_cross_cache(params, cfg, batch["frames"])
        dec = _decode_all(mod, cfg, params, batch, T,
                          cache_extra={"ck": ck, "cv": cv})
    else:
        batch.pop("patches", None)
        full, _ = mod.forward(params, cfg, batch)
        dec = _decode_all(mod, cfg, params, batch, T)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3
    )


def test_chunked_attention_matches_dense():
    from repro.nn import layers as L

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, S, H, D = 2, 100, 4, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, 2, D))
    v = jax.random.normal(ks[2], (B, S, 2, D))
    for window in (None, 17):
        d = L.attention_dense(q, k, v, causal=True, window=window)
        c = L.attention_chunked(q, k, v, causal=True, window=window, block=32)
        np.testing.assert_allclose(np.asarray(c), np.asarray(d),
                                   rtol=1e-5, atol=1e-5)


def test_swa_ring_cache_matches_linear_cache():
    """Ring-buffer SWA cache == full cache with window masking."""
    cfg = cfglib.get_config("granite-3-8b-swa").smoke_variant()
    assert cfg.sliding_window == 16
    mod = get_family(cfg)
    params, _ = mod.init(jax.random.PRNGKey(2), cfg)
    T = 24  # > window -> the ring wraps
    batch = make_batch(cfg, 1, T, key=jax.random.PRNGKey(4))
    full, _ = mod.forward(params, cfg, batch)  # dense path applies window
    dec = _decode_all(mod, cfg, params, batch, T)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_all_assigned_archs_present():
    expected = {
        "whisper-tiny", "qwen2.5-3b", "internvl2-1b", "mamba2-780m",
        "chatglm3-6b", "zamba2-7b", "mixtral-8x7b", "deepseek-moe-16b",
        "granite-3-8b", "phi3-medium-14b",
    }
    assert expected <= set(cfglib.ARCHS)


def test_exact_config_dims():
    """Assigned table dims are encoded exactly."""
    c = cfglib.get_config("qwen2.5-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (36, 2048, 16, 2, 11008, 151936)
    assert c.qkv_bias
    c = cfglib.get_config("mixtral-8x7b")
    assert (c.n_experts, c.moe_top_k, c.sliding_window) == (8, 2, 4096)
    c = cfglib.get_config("deepseek-moe-16b")
    assert (c.n_experts, c.moe_top_k, c.n_shared_experts) == (64, 6, 2)
    c = cfglib.get_config("mamba2-780m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = cfglib.get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = cfglib.get_config("chatglm3-6b")
    assert c.rope_fraction == 0.5 and c.n_kv_heads == 2
    c = cfglib.get_config("phi3-medium-14b")
    assert (c.n_heads, c.n_kv_heads, c.d_ff) == (40, 10, 17920)
    c = cfglib.get_config("whisper-tiny")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.vocab) == (4, 4, 384, 51865)
    c = cfglib.get_config("internvl2-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (24, 896, 14, 151655)
    c = cfglib.get_config("granite-3-8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (40, 4096, 12800, 49155)


def test_moe_gather_dispatch_matches_einsum():
    """§Perf gather dispatch is numerically identical to GShard einsum."""
    import jax
    from repro.nn import moe as M

    key = jax.random.PRNGKey(0)
    p, _ = M.moe_init(key, 32, 64, 4, n_shared=1, shared_d_ff=64)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, 32))
    for cf in (0.5, 1.25):  # with and without dropping
        y1, a1 = M.moe_apply(p, x, top_k=2, capacity_factor=cf,
                             group_size=16, dispatch="einsum")
        y2, a2 = M.moe_apply(p, x, top_k=2, capacity_factor=cf,
                             group_size=16, dispatch="gather")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_pad_heads_identical_function_at_init():
    """Zero-init padding heads leave the forward function unchanged."""
    cfg0 = cfglib.get_config("phi3-medium-14b").smoke_variant().replace(
        remat=False, n_heads=5, n_kv_heads=5, head_dim=16)
    cfg1 = cfg0.replace(pad_heads=8)
    mod = get_family(cfg0)
    batch = make_batch(cfg0, 2, 8)
    p1, _ = mod.init(jax.random.PRNGKey(0), cfg1)
    l1, _ = mod.forward(p1, cfg1, batch)
    # the padded model must produce finite sane logits and its padding
    # heads contribute exactly zero (wq rows and wo rows zeroed)
    assert not np.any(np.isnan(np.asarray(l1, np.float32)))
    assert float(jnp.abs(p1["layers"]["attn"]["wq"][:, :, cfg0.n_heads:]).sum()) == 0.0
