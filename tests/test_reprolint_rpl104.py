"""RPL104 fixtures: recompilation hazards."""
import textwrap

from tools.reprolint import lint_paths


def _lint(tmp_path, source):
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(source))
    viols, n_files = lint_paths(
        [str(f)], select=["RPL104"], repo_root=str(tmp_path)
    )
    assert n_files == 1
    return viols


def test_bad_defaults_on_jitted_fn_flag(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, w=jnp.zeros(3), opts=[1, 2]):
            return x + w
        """,
    )
    msgs = " | ".join(v.message for v in viols)
    assert len(viols) == 2
    assert "array-valued default" in msgs
    assert "unhashable" in msgs


def test_static_argnums_on_array_param_flags(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import functools

        import jax

        @functools.partial(jax.jit, static_argnums=(0,))
        def f(a: jax.Array, k: int):
            return a * k

        @functools.partial(jax.jit, static_argnames=("b",))
        def g(x: jax.Array, b: jax.Array):
            return x + b
        """,
    )
    assert len(viols) == 2
    assert all("retraces" in v.message for v in viols)


def test_tracer_fstring_and_jit_in_loop_flag(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax

        @jax.jit
        def f(x: jax.Array):
            tag = f"val={x}"
            return x, tag

        def sweep(fn, xs):
            outs = []
            for x in xs:
                jf = jax.jit(fn)
                outs.append(jf(x))
            return outs
        """,
    )
    msgs = " | ".join(v.message for v in viols)
    assert len(viols) == 2
    assert "f-string" in msgs
    assert "inside a loop" in msgs


def test_static_idioms_stay_clean(tmp_path):
    # literal defaults, static_argnames on genuinely static params, and
    # per-iteration jit of a *lambda* (deliberate rebind) are all fine.
    viols = _lint(
        tmp_path,
        """
        import functools

        import jax

        @functools.partial(jax.jit, static_argnames=("k", "interpret"))
        def f(x: jax.Array, k: int = 8, interpret: bool = False):
            return x[:k]

        def sweep(sims, s):
            outs = []
            for sim in sims:
                step = jax.jit(lambda st: sim.step_fn(st))
                outs.append(step(s))
            return outs

        step = jax.jit(f)   # module level: fine
        """,
    )
    assert viols == []
