"""RPL102 fixtures: shard-axis discipline for lax collectives."""
import textwrap

from tools.reprolint import lint_paths


def _lint(tmp_path, source):
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(source))
    viols, n_files = lint_paths(
        [str(f)], select=["RPL102"], repo_root=str(tmp_path)
    )
    assert n_files == 1
    return viols


def test_hardcoded_axis_in_library_code_flags(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax

        def shard(self, payload, weight):
            return jax.lax.psum(payload * weight, "data")
        """,
    )
    assert [v.rule for v in viols] == ["RPL102"]
    assert "'data'" in viols[0].message and "psum" in viols[0].message


def test_hardcoded_tuple_and_module_constant_flag(tmp_path):
    viols = _lint(
        tmp_path,
        """
        from jax import lax

        DP = ("data", "pod")

        def agg(x):
            return lax.pmean(x, DP)

        def gather(x):
            return lax.all_gather(x, ("data",))
        """,
    )
    assert len(viols) == 3  # 'data'+'pod' via constant, 'data' literal
    assert all(v.rule == "RPL102" for v in viols)


def test_parameter_derived_axes_stay_clean(tmp_path):
    # the repo's actual idiom: collectives receive axis names from callers
    viols = _lint(
        tmp_path,
        """
        import jax

        def shard(self, payload, axis_names, weight):
            for ax in axis_names:
                payload = jax.lax.all_gather(payload, ax)
            return jax.lax.psum(payload * weight, tuple(axis_names))

        def nested(dp_axes):
            def body(x):
                return jax.lax.pmean(x, dp_axes)   # enclosing-fn parameter
            return body
        """,
    )
    assert viols == []


def test_literal_declared_by_same_module_mesh_stays_clean(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax
        from repro.launch.mesh import make_mesh

        def calibrate(n):
            mesh = make_mesh((n,), ("data",))
            def body(x):
                return jax.lax.psum(x, ("data",))
            return mesh, body
        """,
    )
    assert viols == []
