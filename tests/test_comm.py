"""``repro.comm`` subsystem tests (ISSUE 1 tentpole).

Round-trip property tests per codec, (codec x strategy) aggregation
equivalence against ``dense_allreduce`` in both the simulator and the
``shard_map`` runtime (subprocess CPU mesh), cost-model consistency
(measured <= predicted x 1.05), and the hard_threshold payload guard.
"""
import dataclasses
import textwrap
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import comm
from repro.core import DistributedSim, SparsifierConfig, make_sparsifier
from repro.core.selectors import sparsity_to_k

CODEC_NAMES = sorted(comm.CODECS)
PAYLOAD_STRATEGIES = ["sparse_allgather", "hierarchical"]


def _payload_case(seed: int, L: int, k: int):
    """Random fixed-k payload with distinct indices + (0,0) padding tail."""
    key = jax.random.PRNGKey(seed)
    vals = jax.random.normal(key, (k,))
    idx = jax.random.choice(
        jax.random.fold_in(key, 1), L, (k,), replace=False
    ).astype(jnp.int32)
    n_pad = seed % max(k // 2, 1)
    if n_pad:
        vals = vals.at[-n_pad:].set(0.0)
        idx = idx.at[-n_pad:].set(0)
    return vals, idx


# ---------------------------------------------------------------------------
# codec round-trips (property-based, ISSUE 4 satellite: random shapes x
# sparsities x dtypes replace the old fixed-seed spot checks)
# ---------------------------------------------------------------------------
def _random_payload(seed, L, sparsity, dtype):
    """Fixed-k payload over random data: distinct indices, a (0, 0)
    padding tail, values in the requested dtype."""
    from repro.core.selectors import sparsity_to_k

    k = sparsity_to_k(L, sparsity)
    key = jax.random.PRNGKey(seed)
    vals = (
        3.0 * jax.random.normal(key, (k,), jnp.float32)
    ).astype(dtype)
    idx = jax.random.choice(
        jax.random.fold_in(key, 1), L, (k,), replace=False
    ).astype(jnp.int32)
    n_pad = seed % max(k // 2, 1)
    if n_pad:
        vals = vals.at[-n_pad:].set(0)
        idx = idx.at[-n_pad:].set(0)
    return vals, idx, k


LOSSLESS_NAMES = [n for n in CODEC_NAMES if comm.get_codec(n).lossless]


@pytest.mark.parametrize("name", LOSSLESS_NAMES)
@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 4096),
    st.floats(0.001, 0.9),
    st.sampled_from(["float32", "bfloat16"]),
)
def test_lossless_codec_roundtrip_is_exact(name, seed, L, sparsity, dtype):
    """encode -> decode preserves the scattered contribution *exactly* for
    every lossless codec, over random lengths, sparsities and value
    dtypes. Decode may reorder coordinates and merge (0, 0) padding slots;
    neither changes the scatter-add result by even one ulp (adding 0.0 is
    exact, and distinct indices never collide)."""
    vals, idx, k = _random_payload(seed, L, sparsity, jnp.dtype(dtype))
    codec = comm.get_codec(name)
    # the wire carries f32 values: the reference is the f32-cast scatter
    ref = jnp.zeros(L).at[idx].add(vals.astype(jnp.float32))
    dv, di = codec.decode(codec.encode(vals, idx, L), L)
    assert dv.dtype == jnp.float32
    got = jnp.zeros(L).at[di].add(dv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 4096),
    st.floats(0.001, 0.9),
    st.sampled_from(["float32", "bfloat16"]),
)
def test_q8_roundtrip_error_bounded_by_quantization_step(
    seed, L, sparsity, dtype
):
    """coo_q8's per-coordinate round-trip error is bounded by half its
    quantization step (scale = max|v| / 127, symmetric round-to-nearest),
    and the indices come back exactly."""
    vals, idx, k = _random_payload(seed, L, sparsity, jnp.dtype(dtype))
    c = comm.get_codec("coo_q8")
    p = c.encode(vals, idx, L)
    dv, di = c.decode(p, L)
    np.testing.assert_array_equal(np.asarray(di), np.asarray(idx))
    v32 = np.asarray(vals.astype(jnp.float32))
    amax = float(np.max(np.abs(v32)))
    step = (amax / 127.0) if amax > 0 else 1.0
    err = np.max(np.abs(np.asarray(dv) - v32))
    assert err <= step / 2 + 1e-7 * max(amax, 1.0)


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_codec_static_shapes_and_bit_accounting(name):
    """Payload shapes/dtypes depend only on (L, k), and the wire_bits
    accounting matches the actual encoded buffer sizes exactly."""
    L, k = 200, 16
    codec = comm.get_codec(name)
    shapes = set()
    for seed in range(3):
        vals, idx = _payload_case(seed, L, k)
        p = codec.encode(vals, idx, L)
        shapes.add(
            tuple((kk, v.shape, str(v.dtype)) for kk, v in sorted(p.items()))
        )
        assert comm.payload_nbytes(p) * 8 == codec.wire_bits(L, k)
    assert len(shapes) == 1  # data-independent (XLA-static) layout
    # eval_shape agrees without running the encoder
    ab = jax.eval_shape(
        lambda v, i: codec.encode(v, i, L),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.int32),
    )
    assert comm.payload_nbytes(ab) * 8 == codec.wire_bits(L, k)


def test_coo_idx_delta_narrows_index_dtype():
    assert comm.delta_index_dtype(100) == jnp.int8
    assert comm.delta_index_dtype(1000) == jnp.int16
    assert comm.delta_index_dtype(2**20) == jnp.int32
    L, k = 1000, 32
    c = comm.get_codec("coo_idx_delta")
    assert c.wire_bits(L, k) < comm.get_codec("coo_fp32").wire_bits(L, k)


def test_bitmap_dense_wins_above_one_32nd_sparsity():
    L = 3200
    coo = comm.get_codec("coo_fp32")
    bm = comm.get_codec("bitmap_dense")
    assert bm.wire_bits(L, L // 16) < coo.wire_bits(L, L // 16)  # S = 1/16
    assert bm.wire_bits(L, L // 320) > coo.wire_bits(L, L // 320)  # S « 1/32


# ---------------------------------------------------------------------------
# (codec x strategy) reference equivalence vs dense
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cname", CODEC_NAMES)
@pytest.mark.parametrize("sname", PAYLOAD_STRATEGIES)
def test_reference_aggregation_matches_dense(cname, sname):
    N, L, k = 4, 120, 10
    vals = jnp.stack([_payload_case(s, L, k)[0] for s in range(N)])
    idx = jnp.stack([_payload_case(s, L, k)[1] for s in range(N)])
    w = jnp.full((N,), 1.0 / N)
    ref = jnp.zeros(L)
    for n in range(N):
        ref = ref.at[idx[n]].add(vals[n] / N)
    codec = comm.get_codec(cname)
    payloads = jax.vmap(lambda v, i: codec.encode(v, i, L))(vals, idx)
    got = comm.get_collective(sname).reference(codec, payloads, w, L)
    rel = float(jnp.max(jnp.abs(got - ref))) / (
        float(jnp.max(jnp.abs(ref))) or 1.0
    )
    assert rel < (1e-6 if codec.lossless else 1e-2)


@pytest.mark.parametrize("cname", CODEC_NAMES)
@pytest.mark.parametrize(
    "sname", ["dense_allreduce", "sparse_allgather", "hierarchical"]
)
def test_shard_form_matches_reference_single_device(cname, sname):
    """Collective.shard == Collective.reference on an in-process 1-device
    mesh (axis size 1: the gather/psum are identities, so the shard-form
    plumbing — including the participation hook — is checked without a
    subprocess device farm)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map

    L, k = 96, 8
    codec = comm.get_codec(cname)
    strategy = comm.get_collective(sname)
    vals, idx = _payload_case(3, L, k)
    payload = codec.encode(vals, idx, L)
    stacked = jax.tree.map(lambda x: x[None], payload)
    ref = strategy.reference(
        codec, stacked, jnp.ones((1,)), L
    )
    mesh = make_mesh((1,), ("data",))
    in_specs = jax.tree.map(
        lambda x: P(*(("data",) + (None,) * x.ndim)), payload
    )

    def body(p):
        local = jax.tree.map(lambda x: x[0], p)
        full = strategy.shard(codec, local, L, ("data",), 1.0)
        part = strategy.shard(
            codec, local, L, ("data",), 1.0, participation=jnp.float32(1.0)
        )
        return full, part

    with mesh:
        got_full, got_part = shard_map(
            body,
            mesh=mesh,
            in_specs=(in_specs,),
            out_specs=(P(None), P(None)),
            check_vma=False,
        )(stacked)
    np.testing.assert_allclose(
        np.asarray(got_full), np.asarray(ref), rtol=1e-6, atol=1e-7
    )
    # a unit participation mask must not change the shard-form numerics
    np.testing.assert_allclose(
        np.asarray(got_part), np.asarray(got_full), rtol=1e-6, atol=1e-7
    )


# ---------------------------------------------------------------------------
# simulator end-to-end: every pair matches dense_allreduce training
# ---------------------------------------------------------------------------
def _toy_setup():
    x = jnp.array([[100.0, 1.0], [-100.0, 1.0]])

    def grad_fn(theta, n):
        xn = x[n]
        e = jnp.exp(-jnp.dot(theta, xn))
        return -e * xn / (1 + e)

    return grad_fn


@pytest.mark.parametrize("cname", CODEC_NAMES)
@pytest.mark.parametrize("sname", PAYLOAD_STRATEGIES)
def test_simulator_codec_strategy_matches_dense(cname, sname):
    grad_fn = _toy_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0)
    ref_sim = DistributedSim(grad_fn, 2, 2, cfg, learning_rate=0.9)
    fin_ref, _ = ref_sim.run(jnp.array([0.0, 1.0]), 30)
    sim = DistributedSim(
        grad_fn, 2, 2, cfg, learning_rate=0.9, codec=cname, collective=sname
    )
    fin, _ = sim.run(jnp.array([0.0, 1.0]), 30)
    ref = np.asarray(fin_ref.theta)
    rel = np.max(np.abs(np.asarray(fin.theta) - ref)) / max(
        np.max(np.abs(ref)), 1e-30
    )
    assert rel < (1e-5 if comm.get_codec(cname).lossless else 1e-2)


def test_simulator_q8_error_feedback_converges():
    """With the quantization residual folded into eps, q8 training tracks
    the exact run; without feedback the bias would accumulate."""
    grad_fn = _toy_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0)

    def loss(theta):
        x = jnp.array([[100.0, 1.0], [-100.0, 1.0]])
        return float(jnp.mean(jnp.log(1 + jnp.exp(-x @ theta))))

    sim = DistributedSim(
        grad_fn, 2, 2, cfg, learning_rate=0.9,
        codec="coo_q8", collective="sparse_allgather",
    )
    fin, _ = sim.run(jnp.array([0.0, 1.0]), 60)
    assert loss(fin.theta) < 0.05  # same convergence bar as the fig1 test


def test_none_sparsifier_payload_collective_stays_dense():
    """kind='none' has no fixed-k payload; with a payload collective the
    simulator must aggregate the full dense gradient (like _spa_leaf), not
    silently truncate it to k coordinates (regression)."""
    grad_fn = _toy_setup()
    cfg = SparsifierConfig(kind="none", sparsity=0.5)
    ref = DistributedSim(grad_fn, 2, 2, cfg, learning_rate=0.9)
    sim = DistributedSim(
        grad_fn, 2, 2, cfg, learning_rate=0.9,
        collective="sparse_allgather",
    )
    st_ref, st = ref.init(jnp.array([0.0, 1.0])), sim.init(
        jnp.array([0.0, 1.0])
    )
    _, g_ref = ref.step_fn(st_ref)
    _, g = sim.step_fn(st)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-6)


def test_dense_wire_bytes_track_state_dtype():
    """bf16 eps state psums a bf16 vector — comm_bytes must halve, not
    assume 4-byte words (regression)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        comm_round_bytes,
    )

    class _Mesh:
        shape: ClassVar[dict] = {"data": 4}

    plan = LeafPlan((64,), (64,), 64, 4, P(None))
    f32 = DistConfig(aggregation="dense_allreduce", state_dtype="float32")
    bf16 = DistConfig(aggregation="dense_allreduce", state_dtype="bfloat16")
    p32, m32 = comm_round_bytes(plan, f32, _Mesh())
    p16, m16 = comm_round_bytes(plan, bf16, _Mesh())
    assert (p16, m16) == (p32 // 2, m32 // 2)
    # kind="none" pmeans in f32 regardless of state dtype
    none16 = dataclasses.replace(
        bf16, sparsifier=SparsifierConfig(kind="none")
    )
    assert comm_round_bytes(plan, none16, _Mesh()) == (p32, m32)


def test_hard_threshold_payload_collective_raises():
    grad_fn = _toy_setup()
    cfg = SparsifierConfig(kind="hard_threshold", threshold=0.1)
    with pytest.raises(ValueError, match="hard_threshold"):
        DistributedSim(grad_fn, 2, 2, cfg, collective="sparse_allgather")
    with pytest.raises(ValueError, match="hard_threshold"):
        DistributedSim(grad_fn, 2, 2, cfg, aggregation="sparse_allgather")
    # dense aggregation stays supported
    DistributedSim(grad_fn, 2, 2, cfg)


def test_q8_sim_state_matches_compact_runtime_state():
    """The dense-state simulator path and the compact distributed-runtime
    path must evolve identically under a lossy codec: eps carries the
    quantization residual and RegTop-k conditions on the *decoded* payload
    in both (regression for the a_prev/sent_vals mismatch)."""
    from repro.core import compact as C
    from repro.core.selectors import mask_to_payload

    L, k, steps = 32, 4, 8
    cfg = SparsifierConfig(kind="regtopk", sparsity=k / L, mu=1.0, omega=0.5)
    codec = comm.get_codec("coo_q8")
    sp = make_sparsifier(cfg)
    dense_st = sp.init(L)
    comp_st = C.compact_init(L, k)
    g_prev = jnp.zeros(L)
    key = jax.random.PRNGKey(0)
    for t in range(steps):
        key, sk = jax.random.split(key)
        g = jax.random.normal(sk, (L,))
        # dense-state path (simulator algebra)
        ghat, mask, new_ws = sp.step(dense_st, g, g_prev)
        vals, idx = mask_to_payload(mask, ghat, k)
        dv, di = codec.decode(codec.encode(vals, idx, L), L)
        sent = jnp.zeros(L).at[di].add(dv)
        intended = jnp.zeros(L).at[idx].add(vals)
        delta = sent - intended
        dense_st = sp.on_wire_residual(new_ws, delta)
        # compact path (distributed runtime algebra)
        a, cvals, cidx = C.compact_select(cfg, comp_st, g, k)
        cdv, cdi = codec.decode(codec.encode(cvals, cidx, L), L)
        csent = jnp.zeros(L).at[cdi].add(cdv)
        agg = 0.5 * csent
        comp_st = C.compact_finalize_sent(comp_st, a, cdv, cdi, csent, agg)
        g_prev = agg
        assert bool((jnp.sort(cidx) == jnp.sort(idx)).all()), f"mask @ t={t}"
        np.testing.assert_allclose(
            np.asarray(comp_st.eps), np.asarray(dense_st.eps), atol=1e-6
        )


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cname", CODEC_NAMES)
@pytest.mark.parametrize(
    "sname", ["dense_allreduce", "sparse_allgather", "hierarchical"]
)
def test_measured_within_predicted(cname, sname):
    L, k, dp = 512, 16, (4, 2)
    codec = comm.get_codec(cname)
    vals, idx = _payload_case(0, L, k)
    payload = codec.encode(vals, idx, L)
    pred = comm.predicted_bytes(codec, sname, L, k, dp)
    meas = comm.measured_bytes(sname, L, payload, dp)
    assert meas <= pred * 1.05
    est = comm.predict(codec, sname, L, k, dp)
    assert est.bytes_on_wire == pred
    assert est.seconds > 0 and est.n_messages > 0


def test_hierarchical_compresses_the_outer_slow_axes():
    """Mesh dp axes are ordered outermost (slow) first — ("pod", "data").
    Hierarchical must move *payloads* over the outer axes and the dense
    vector only over the innermost fast axis: growing the outer axis must
    not grow the dense term."""
    L, k = 100_000, 100
    pb = comm.get_codec("coo_fp32").wire_bits(L, k) // 8
    dense_term = lambda a: 2 * (a - 1) / a * L * 4
    two_pods = comm.predicted_bytes(
        "coo_fp32", "hierarchical", L, k, (2, 8)
    )
    four_pods = comm.predicted_bytes(
        "coo_fp32", "hierarchical", L, k, (4, 8)
    )
    # inter (outer, pod) term is payload-sized; intra (inner, data) is dense
    assert two_pods == int(np.ceil((2 - 1) * pb + dense_term(8)))
    assert four_pods - two_pods == 2 * pb  # only payload bytes grow


def test_sparse_beats_dense_at_low_sparsity():
    L, N = 100_000, 16
    k = sparsity_to_k(L, 0.001)
    dense = comm.predicted_bytes("coo_fp32", "dense_allreduce", L, k, (N,))
    sparse = comm.predicted_bytes("coo_fp32", "sparse_allgather", L, k, (N,))
    assert sparse < dense


def test_wire_words_from_codec_wire_bits():
    # the removed ``cost.wire_words_per_worker`` shim's word counts fall
    # straight out of ``Codec.wire_bits`` (migration recipe: docs/comm.md)
    # — dense ships L f32 words, the fp32-COO allgather 2*k words/worker.
    L, k, N = 1000, 10, 4
    assert comm.get_codec("coo_fp32").wire_bits(L, k) * N // 32 == 80
    dense_words = L  # the dense vector itself, one f32 word per coord
    assert dense_words == 1000
    # and the shim's ValueError on unknown modes lives on in the registry
    with pytest.raises(ValueError, match="codec"):
        comm.get_codec("bogus")


# ---------------------------------------------------------------------------
# DGC momentum is config-threaded (ISSUE 1 satellite)
# ---------------------------------------------------------------------------
def test_dgc_momentum_from_config():
    g = jnp.array([4.0, -3.0, 1.0, 0.5])
    for m in (0.0, 0.5, 0.9):
        sp = make_sparsifier(
            SparsifierConfig(kind="dgc", sparsity=0.5, momentum=m)
        )
        state = sp.init(4)
        _, _, s1 = sp.step(state, g, jnp.zeros(4))
        g2 = jnp.array([0.0, 0.0, 1.0, 0.0])
        ghat2, _, _ = sp.step(s1, g2, jnp.zeros(4))
        # round 1 at idx 2: v = eps + (m*u + g2) = 1 + m*1 + 1
        np.testing.assert_allclose(
            float(ghat2[2]), 2.0 + m, rtol=1e-6
        )


# ---------------------------------------------------------------------------
# shard_map runtime equivalence (subprocess, 8 forced CPU devices)
# ---------------------------------------------------------------------------
SUB_CODE = textwrap.dedent(
    """
    import json
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    from repro.models import ModelConfig, get_family
    from repro.core.distributed import (DistConfig, assemble,
                                        init_sparsifier_state)
    from repro.core.sparsify import SparsifierConfig
    from repro.optim import OptConfig, make_optimizer
    from repro.data import TokenPipeline

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=256, remat=False)
    mod = get_family(cfg)

    def train(codec, collective, steps=8):
        dist = DistConfig(
            sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.05,
                                        mu=1.0),
            optimizer=OptConfig(kind="adam", learning_rate=3e-3),
            codec=codec, collective=collective, microbatches=1,
            dp_axes=("data",))
        asm = assemble(mod, cfg, dist, mesh)
        params, _ = mod.init(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(dist.optimizer)
        opt_state = opt.init(params)
        sp_state, _ = init_sparsifier_state(asm.plan, 4, mesh, ("data",),
                                            jnp.float32)
        pipe = TokenPipeline(cfg, global_batch=8, seq=32)
        step = jax.jit(asm.train_step)
        losses = []
        with mesh:
            for t in range(steps):
                params, opt_state, sp_state, m = step(
                    params, opt_state, sp_state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        return losses, (float(m["comm_bytes"]),
                        float(m["comm_bytes_predicted"]))

    ref, _ = train("coo_fp32", "dense_allreduce")
    out = {}
    for codec in {CODECS}:
        for coll in {STRATEGIES}:
            l, (meas, pred) = train(codec, coll)
            out[codec + "/" + coll] = {
                "diff": max(abs(a - b) for a, b in zip(ref, l)),
                "meas": meas, "pred": pred,
                "lossless": codec != "coo_q8"}
    print(json.dumps(out))
    """
)


@pytest.mark.parametrize("group", [0, 1])
def test_shard_map_codec_strategy_matches_dense(group):
    """Every (codec, strategy) pair matches dense_allreduce in the real
    shard_map runtime, and measured wire bytes stay within the prediction.
    Split into two subprocesses to keep per-case compile time bounded."""
    from tests.test_distributed import run_sub

    codecs = (
        ["coo_fp32", "coo_idx_delta"] if group == 0
        else ["bitmap_dense", "coo_q8"]
    )
    code = SUB_CODE.replace("{CODECS}", repr(codecs)).replace(
        "{STRATEGIES}", repr(PAYLOAD_STRATEGIES)
    )
    res = run_sub(code)
    assert set(res) == {
        f"{c}/{s}" for c in codecs for s in PAYLOAD_STRATEGIES
    }
    for name, r in res.items():
        tol = 1e-4 if r["lossless"] else 1e-2
        assert r["diff"] < tol, f"{name}: loss diverged by {r['diff']}"
        assert r["meas"] <= r["pred"] * 1.05, f"{name}: wire accounting"
