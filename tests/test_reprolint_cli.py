"""CLI-level reprolint tests: exit codes, output format, suppressions,
and the acceptance-criterion demonstration that a seeded violation fails
the same invocation the CI `static` job runs.
"""
import os
import re
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *args],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
    )


def write(tmp_path, name, source):
    f = tmp_path / name
    f.write_text(textwrap.dedent(source))
    return str(f)


SEEDED = """
    import jax

    @jax.jit
    def f(x: jax.Array):
        if x > 0:
            return x
        return -x
"""


def test_clean_file_exits_zero(tmp_path):
    path = write(
        tmp_path,
        "clean.py",
        """
        import jax.numpy as jnp

        def f(x):
            return jnp.where(x > 0, x, 0.0)
        """,
    )
    proc = run_cli(path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_seeded_violation_fails_the_static_invocation(tmp_path):
    # acceptance criterion: the exact CI invocation demonstrably fails
    # on a seeded violation.
    path = write(tmp_path, "seeded.py", SEEDED)
    proc = run_cli(path)
    assert proc.returncode == 1
    line = proc.stdout.splitlines()[0]
    assert re.match(r".*seeded\.py:\d+:\d+: RPL101 ", line), line


def test_suppression_silences_and_unused_suppression_fails(tmp_path):
    suppressed = write(
        tmp_path,
        "suppressed.py",
        """
        import jax

        @jax.jit
        def f(x: jax.Array):
            if x > 0:  # reprolint: disable=RPL101
                return x
            return -x
        """,
    )
    proc = run_cli(suppressed)
    assert proc.returncode == 0, proc.stdout

    unused = write(
        tmp_path,
        "unused.py",
        """
        def g(x):  # reprolint: disable=RPL101
            return x
        """,
    )
    proc = run_cli(unused)
    assert proc.returncode == 1
    assert "RPL100" in proc.stdout and "unused suppression" in proc.stdout


def test_suppression_inside_string_literal_is_inert(tmp_path):
    path = write(
        tmp_path,
        "stringy.py",
        '''
        EXAMPLE = """
        x = 1  # reprolint: disable=RPL101
        """
        ''',
    )
    proc = run_cli(path)
    assert proc.returncode == 0, proc.stdout


def test_list_rules_covers_all_ids():
    proc = run_cli("--list-rules")
    assert proc.returncode == 0
    for rule in ("RPL100", "RPL101", "RPL102", "RPL103", "RPL104", "RPL105"):
        assert rule in proc.stdout


def test_repo_tree_is_clean():
    # the tree this PR ships must satisfy its own linter (dogfood).
    proc = run_cli("src", "tests", "benchmarks")
    assert proc.returncode == 0, proc.stdout + proc.stderr
