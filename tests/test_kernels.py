"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.kernels import ops, ref
from repro.kernels.block_topk import block_topk_candidates
from repro.kernels.regtopk_score import regtopk_score as raw_score
from repro.kernels.threshold_topk import count_above, global_max

SHAPES = [(8, 1024), (16, 1024), (64, 1024)]


def _rand(key, shape, dtype=jnp.float32, scale=3.0):
    return scale * jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# kernel <-> reference parity matrix (ISSUE 4 satellite): every kernel vs
# its kernels/ref.py oracle over dtype x y x shape — including
# non-multiple-of-block lengths through the ops wrappers — parameterized
# instead of hand-picked cases.
# ---------------------------------------------------------------------------
PARITY_DTYPES = ["float32", "bfloat16"]
PARITY_YS = [0.5, 1.0, 2.0]
# one tile-aligned length, two that exercise the pad/unpad path
PARITY_LENGTHS = [100, 8192, 10_000]


def _parity_inputs(n, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    dt = jnp.dtype(dtype)
    a, a_prev, g_prev = (_rand(k, (n,)).astype(dt) for k in ks[:3])
    s_prev = (jax.random.uniform(ks[3], (n,)) > 0.5).astype(dt)
    return a, a_prev, s_prev, g_prev


@pytest.mark.parametrize("dtype", PARITY_DTYPES)
@pytest.mark.parametrize("y", PARITY_YS)
@pytest.mark.parametrize("n", PARITY_LENGTHS)
def test_regtopk_score_parity_matrix(dtype, y, n):
    """ops.regtopk_score == the jnp oracle on the same (f32-cast, as the
    wrapper's layout contract specifies) inputs, over the full grid."""
    a, a_prev, s_prev, g_prev = _parity_inputs(n, dtype)
    got = ops.regtopk_score(a, a_prev, s_prev, g_prev, omega=0.25, mu=1.5,
                            y=y, interpret=True)
    f32 = [x.astype(jnp.float32) for x in (a, a_prev, s_prev, g_prev)]
    want = ref.regtopk_score_ref(*f32, omega=0.25, mu=1.5, y=y)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


def test_regtopk_score_large_multi_tile():
    """64-tile (65,536-element) ops-wrapper parity — the grid's lengths
    stay small for speed, so keep one large case that exercises many-tile
    grid logic (was test_regtopk_score_ops_arbitrary_length's top size)."""
    n = 65_536
    a, a_prev, s_prev, g_prev = _parity_inputs(n, "float32", seed=1)
    got = ops.regtopk_score(a, a_prev, s_prev, g_prev, omega=0.1, mu=2.0,
                            interpret=True)
    want = ref.regtopk_score_ref(a, a_prev, s_prev, g_prev, omega=0.1,
                                 mu=2.0)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("mu", [0.5, 1.0, 7.3])
def test_regtopk_score_raw_kernel_mu_sweep(mu):
    """The raw tiled kernel against the oracle across the mu range."""
    shape = (16, 1024)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    a, a_prev, g_prev = (_rand(k, shape) for k in ks[:3])
    s_prev = (jax.random.uniform(ks[3], shape) > 0.5).astype(jnp.float32)
    got = raw_score(a, a_prev, s_prev, g_prev, omega=0.05, mu=mu,
                    interpret=True)
    want = ref.regtopk_score_ref(a, a_prev, s_prev, g_prev, omega=0.05,
                                 mu=mu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


def test_regtopk_score_zero_denominator_no_nan():
    a = jnp.zeros((8, 1024))
    s_prev = jnp.ones((8, 1024))
    got = raw_score(a, a, s_prev, a, omega=0.1, mu=1.0, interpret=True)
    assert not np.any(np.isnan(np.asarray(got)))


def test_regtopk_score_matches_dense_sparsifier_scoring():
    """Kernel == the simulator's RegTopK._score on the same inputs."""
    from repro.core.sparsify import SparsifierConfig, SparsifierState, RegTopK

    n = 4096
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    a, a_prev, g_prev = (_rand(k, (n,)) for k in ks[:3])
    s_prev = (jax.random.uniform(ks[3], (n,)) > 0.5).astype(jnp.float32)
    cfg = SparsifierConfig(kind="regtopk", mu=1.5, omega=0.25, q_const=1e9)
    sp = RegTopK(cfg)
    st_ = SparsifierState(  # reprolint: disable=RPL106 (kernel parity)
        eps=jnp.zeros(n), a_prev=a_prev, s_prev=s_prev,
        t=jnp.ones((), jnp.int32))
    want = sp._score(st_, a, g_prev)
    got = ops.regtopk_score(a, a_prev, s_prev, g_prev, omega=0.25, mu=1.5,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("y", PARITY_YS)
def test_regtopk_score_y_exponent_matches_dense(y):
    """Contract: the kernel must match RegTopK._score — including the
    Remark-4 prior exponent y (regression: the kernel ignored y)."""
    from repro.core.sparsify import SparsifierConfig, SparsifierState, RegTopK

    n = 8192
    a, a_prev, s_prev, g_prev = _parity_inputs(n, "float32", seed=8)
    cfg = SparsifierConfig(kind="regtopk", mu=1.5, omega=0.25, y=y)
    sp = RegTopK(cfg)
    st_ = SparsifierState(  # reprolint: disable=RPL106 (kernel parity)
        eps=jnp.zeros(n), a_prev=a_prev, s_prev=s_prev,
        t=jnp.ones((), jnp.int32))
    want = sp._score(st_, a, g_prev)
    got = ops.regtopk_score(a, a_prev, s_prev, g_prev, omega=0.25, mu=1.5,
                            y=y, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# threshold_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", PARITY_DTYPES)
@pytest.mark.parametrize("n", PARITY_LENGTHS)
def test_threshold_topk_parity_matrix(dtype, n):
    """ops.threshold_topk_mask == the pure-jnp selector on the f32-cast
    flat score — dtype x shape grid including pad/unpad lengths (zero
    padding must never be selected)."""
    from repro.core.selectors import threshold_topk_mask as sel_mask

    score = jnp.abs(_rand(jax.random.PRNGKey(11), (n,))).astype(
        jnp.dtype(dtype)
    )
    k = max(1, n // 50)
    got = ops.threshold_topk_mask(score, k, interpret=True)
    want = sel_mask(score.astype(jnp.float32), k)
    assert got.shape == (n,)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(want).astype(got.dtype)
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_count_and_max_kernels(shape):
    score = jnp.abs(_rand(jax.random.PRNGKey(3), shape))
    tau = jnp.float32(1.7)
    got = count_above(score, tau, interpret=True)
    assert int(got) == int(ref.count_above_ref(score, tau))
    gm = global_max(score, interpret=True)
    np.testing.assert_allclose(float(gm), float(ref.global_max_ref(score)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 2000))
def test_threshold_topk_mask_contains_topk(seed, k):
    score = jnp.abs(_rand(jax.random.PRNGKey(seed), (16, 1024)))
    k = min(k, score.size)
    mask = ops.threshold_topk_mask(score, k, interpret=True)
    m = np.asarray(mask).reshape(-1)
    s = np.asarray(score).reshape(-1)
    assert m.sum() >= k
    # every exact top-k element is inside the mask
    kth = np.sort(s)[-k]
    assert (s[m > 0] >= kth - 1e-6).all() or m.sum() == score.size
    got_ref = ref.threshold_topk_mask_ref(score, k)
    np.testing.assert_array_equal(m, np.asarray(got_ref).reshape(-1))


# ---------------------------------------------------------------------------
# block_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("m", [4, 8])
def test_block_topk_candidates_match_ref(shape, m):
    score = jnp.abs(_rand(jax.random.PRNGKey(5), shape))
    vals, idx = block_topk_candidates(score, m=m, interpret=True)
    rvals, ridx = ref.block_topk_candidates_ref(score, m=m)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


@pytest.mark.parametrize("dtype", PARITY_DTYPES)
@pytest.mark.parametrize("n", PARITY_LENGTHS)
def test_hierarchical_topk_parity_matrix(dtype, n):
    """ops.hierarchical_topk (block candidates + exact reduce, through the
    pad/unpad layout) recovers exactly lax.top_k on the f32-cast score for
    small k — dtype x non-multiple-of-block length grid."""
    score = jnp.abs(_rand(jax.random.PRNGKey(12), (n,))).astype(
        jnp.dtype(dtype)
    )
    k = 4
    vals, idx = ops.hierarchical_topk(score, k, m=8, interpret=True)
    want_v, want_i = jax.lax.top_k(score.astype(jnp.float32).reshape(-1), k)
    np.testing.assert_allclose(
        np.asarray(vals), np.asarray(want_v), rtol=1e-6
    )
    assert set(np.asarray(idx).tolist()) == set(np.asarray(want_i).tolist())


def test_threshold_topk_zero_score_kernel_matches_selector_fix():
    """Kernel parity with the selectors.threshold_topk_mask zero-score fix:
    an all-zero score (or zero padding slots) must never be selected."""
    m = ops.threshold_topk_mask(jnp.zeros((8192,)), 16, interpret=True)
    assert float(np.asarray(m).sum()) == 0.0
    # fewer positives than k: only the positives come back
    score = jnp.zeros((8192,)).at[jnp.array([5, 900])].set(3.0)
    m2 = np.asarray(ops.threshold_topk_mask(score, 16, interpret=True))
    np.testing.assert_array_equal(np.nonzero(m2)[0], [5, 900])


# ---------------------------------------------------------------------------
# fused select→encode pipeline (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------
FUSED_LENGTHS = [100, 8192, 65_536]  # single padded tile / 1 tile / 8 tiles


def _fused_k(n):
    return max(2, n // 512)


@pytest.mark.parametrize("dtype", PARITY_DTYPES)
@pytest.mark.parametrize("n", FUSED_LENGTHS)
@pytest.mark.parametrize("codec_name", ["coo_fp32", "coo_q8"])
def test_fused_select_encode_parity_matrix(dtype, n, codec_name):
    """Fused pipeline payload == the unfused oracle's, through the codec's
    fused epilogue, bit-for-bit — dtype x length (incl. padded and
    multi-tile) x codec grid. Inputs f32-cast per the ops layout
    contract; the certificate must hold on Gaussian scores at these
    shapes, so the fast path (not the fallback) is what's tested."""
    from repro import comm
    from repro.comm import fastpath

    a, a_prev, s_prev, g_prev = (
        x.astype(jnp.float32)
        for x in _parity_inputs(n, dtype, seed=13)
    )
    k = _fused_k(n)
    m = fastpath.candidate_budget(n, k)
    vals, idx, ok = ops.fused_select_encode(
        a, a_prev, s_prev, g_prev, k=k, omega=0.25, mu=1.5, m=m,
        interpret=True,
    )
    assert bool(ok), "certificate should hold on Gaussian scores"
    want_v, want_i = ref.fused_select_encode_ref(
        a, a_prev, s_prev, g_prev, k, omega=0.25, mu=1.5
    )
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_i))
    codec = comm.get_codec(codec_name)
    assert codec.supports_fused
    fused_payload = codec.encode_fused(vals, idx, n)
    ref_payload = codec.encode(want_v, want_i, n)
    for key in fused_payload:
        np.testing.assert_array_equal(
            np.asarray(fused_payload[key]), np.asarray(ref_payload[key]),
            err_msg=f"{codec_name} payload leaf {key!r}",
        )


@pytest.mark.parametrize("y", PARITY_YS)
def test_fused_select_encode_y_exponent(y):
    """The Remark-4 prior exponent threads through the fused score."""
    n, k = 8192, 16
    a, a_prev, s_prev, g_prev = _parity_inputs(n, "float32", seed=21)
    vals, idx, ok = ops.fused_select_encode(
        a, a_prev, s_prev, g_prev, k=k, omega=0.25, mu=1.5, y=y,
        interpret=True,
    )
    assert bool(ok)
    want_v, want_i = ref.fused_select_encode_ref(
        a, a_prev, s_prev, g_prev, k, omega=0.25, mu=1.5, y=y
    )
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_i))


def test_fused_certificate_fails_on_hidden_winners():
    """Adversarial mass concentration: more top-k winners inside one tile
    than its candidate budget — the exactness certificate must refuse the
    fast path (callers then lax.cond to dense selection)."""
    n, k, m = 65_536, 20, 16
    z = jnp.zeros(n)
    a = z.at[jnp.arange(32)].set(jnp.arange(32, 0, -1).astype(jnp.float32))
    _, _, ok = ops.fused_select_encode(
        a, z, z, z, k=k, omega=0.25, mu=1.5, m=m, interpret=True
    )
    assert not bool(ok)


def test_fused_certificate_fails_on_zero_scores():
    """tau == 0 (not enough positive scores) never certifies: zero scores
    are never selected on the fast path, which also keeps padding flat
    indices out of the payload."""
    n = 8192
    z = jnp.zeros(n)
    _, _, ok = ops.fused_select_encode(
        z, z, z, z, k=8, omega=0.25, mu=1.5, interpret=True
    )
    assert not bool(ok)
    # fewer positives than k: same story
    a = z.at[jnp.array([5, 900])].set(3.0)
    _, _, ok2 = ops.fused_select_encode(
        a, z, z, z, k=8, omega=0.25, mu=1.5, interpret=True
    )
    assert not bool(ok2)


def test_fused_compact_select_falls_back_bit_for_bit():
    """End-to-end routing through compact_select(fastpath="on") when the
    certificate fails: the lax.cond fallback must still produce exactly
    the dense path's payload."""
    from repro.core import compact as C
    from repro.core.sparsify import SparsifierConfig

    L, k = 65_536, 20
    cfg = SparsifierConfig(kind="topk", sparsity=k / L)
    st = C.compact_init(L, k)
    g = (
        jnp.zeros(L)
        .at[jnp.arange(40)]
        .set(jnp.arange(40, 0, -1).astype(jnp.float32))
    )
    a1, v1, i1 = C.compact_select(cfg, st, g, k)
    a2, v2, i2 = C.compact_select(cfg, st, g, k, fastpath="on")
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_fused_fastpath_y_tie_collapse_regression():
    """Regression: ``x^y`` preserves order but not *ties* — with y=0.5,
    two magnitudes one ulp apart both sqrt to the same f32, and the fused
    kernel (which used to apply ``|a|^y`` where the dense path scores
    plain ``|a|``: all of topk, regtopk's round 0) silently selected a
    different, certificate-blessed payload order. topk must score with
    y forced to 1; regtopk with y != 1 must take the dense fallback on
    round 0."""
    from repro.core import compact as C
    from repro.core.sparsify import SparsifierConfig

    L, k = 8192, 2
    g = jnp.zeros(L).at[jnp.array([50, 100])].set(
        jnp.array([1.0, 1.0000001])
    )
    assert float(jnp.sqrt(g[50])) == float(jnp.sqrt(g[100]))  # f32 tie
    for kind in ("topk", "regtopk"):
        cfg = SparsifierConfig(kind=kind, sparsity=k / L, y=0.5, mu=1.0)
        st = C.compact_init(L, k)
        a1, v1, i1 = C.compact_select(cfg, st, g, k)
        a2, v2, i2 = C.compact_select(cfg, st, g, k, fastpath="on")
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2), kind)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2), kind)
        # regtopk round 1 (t > 0): both paths apply ^y — fused may engage
        agg = 0.5 * jnp.zeros(L).at[i1].add(v1)
        st1 = C.compact_finalize(st, a1, v1, i1, agg)
        b1, w1, j1 = C.compact_select(cfg, st1, g, k)
        b2, w2, j2 = C.compact_select(cfg, st1, g, k, fastpath="on")
        np.testing.assert_array_equal(np.asarray(j1), np.asarray(j2), kind)
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2), kind)
    # an unsaturated regularizer (tanh < 1 scales every unsent score and
    # can also collapse ties) is not fusable for either kind
    from repro.comm import fastpath as fp

    assert not fp.config_fusable(
        SparsifierConfig(kind="topk", sparsity=0.01, mu=1e9)
    )[0]
    # bf16 compact state never routes fused (scores would move to f32)
    cfg = SparsifierConfig(kind="topk", sparsity=k / L)
    st16 = C.compact_init(L, k, jnp.bfloat16)
    a1, v1, i1 = C.compact_select(cfg, st16, g.astype(jnp.bfloat16), k)
    a2, v2, i2 = C.compact_select(
        cfg, st16, g.astype(jnp.bfloat16), k, fastpath="on"
    )
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_hierarchical_topk_exact_when_k_small():
    score = jnp.abs(_rand(jax.random.PRNGKey(6), (32, 1024)))
    k = 4
    vals, idx = ops.hierarchical_topk(score, k, m=8, interpret=True)
    want_v, want_i = jax.lax.top_k(score.reshape(-1), k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v), rtol=1e-6)
    assert set(np.asarray(idx).tolist()) == set(np.asarray(want_i).tolist())


def test_hierarchical_topk_quality_at_realistic_sparsity():
    """At S=0.1% with m=8 per 8k-tile, the candidate set recovers ~all of
    the exact top-k on Gaussian scores (selection-quality guarantee used
    by the serving-path selector)."""
    score = jnp.abs(_rand(jax.random.PRNGKey(7), (256, 1024)))
    k = int(0.0005 * score.size)  # 131 of 256 candidate slots
    vals, idx = ops.hierarchical_topk(score, k, m=8, interpret=True)
    want_v, want_i = jax.lax.top_k(score.reshape(-1), k)
    overlap = len(set(np.asarray(idx).tolist())
                  & set(np.asarray(want_i).tolist()))
    assert overlap >= int(0.97 * k)
