"""Unit tests for optimizer / data / checkpoint / sharding substrates."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _hyp import given, settings, st
from repro.checkpoint import restore, save
from repro.data import TokenPipeline
from repro.data.pipeline import make_linreg
from repro.models import ModelConfig
from repro.nn.sharding import resolve_spec
from repro.optim import OptConfig, make_optimizer


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.0)}
    grad_fn = jax.grad(
        lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    )
    return params, grad_fn


@pytest.mark.parametrize("kind", ["sgd", "momentum", "adam"])
def test_optimizers_descend_quadratic(kind):
    params, grad_fn = _quad_problem()
    opt = make_optimizer(OptConfig(kind=kind, learning_rate=0.1))
    state = opt.init(params)
    for _ in range(120):
        params, state = opt.update(grad_fn(params), state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert abs(float(params["b"])) < 1e-2


def test_adam_bf16_moments_descend():
    params, grad_fn = _quad_problem()
    opt = make_optimizer(
        OptConfig(kind="adam", learning_rate=0.1, moment_dtype="bfloat16")
    )
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    for _ in range(150):
        params, state = opt.update(grad_fn(params), state, params)
    assert float(jnp.abs(params["w"]).max()) < 5e-2


def test_grad_clip():
    params, _ = _quad_problem()
    opt = make_optimizer(OptConfig(kind="sgd", learning_rate=1.0, grad_clip=0.1))
    state = opt.init(params)
    g = {"w": jnp.array([100.0, 0.0]), "b": jnp.array(0.0)}
    new, _ = opt.update(g, state, params)
    assert abs(float(new["w"][0] - params["w"][0])) <= 0.1 + 1e-6


def test_warmup_schedule():
    params, grad_fn = _quad_problem()
    opt = make_optimizer(
        OptConfig(kind="sgd", learning_rate=1.0, warmup_steps=10)
    )
    state = opt.init(params)
    g = grad_fn(params)
    p1, state = opt.update(g, state, params)
    # first step lr = 1/10 -> small move
    assert abs(float(p1["w"][0] - params["w"][0])) < 1.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_token_pipeline_deterministic_and_resumable():
    cfg = ModelConfig(vocab=128)
    pipe = TokenPipeline(cfg, global_batch=4, seq=16, seed=3)
    b1 = pipe.batch_at(7)
    b2 = pipe.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipe.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert int(b1["tokens"].max()) < 128


def test_token_pipeline_learnable_structure():
    """Labels correlate with recent tokens (induction) -> learnable."""
    cfg = ModelConfig(vocab=128)
    pipe = TokenPipeline(cfg, global_batch=8, seq=64)
    b = pipe.batch_at(0)
    recent = np.roll(np.asarray(b["tokens"]), 3, axis=1)
    frac = (np.asarray(b["labels"]) == recent).mean()
    assert frac > 0.3  # ~0.5 by construction


def test_linreg_generator_optimum_is_stationary():
    data = make_linreg(0, 4, 10, 50)
    # gradient of the global loss at theta* is ~0
    r = jnp.einsum("ndj,j->nd", data.X, data.theta_star) - data.y
    g = jnp.einsum("ndj,nd->j", data.X, r)
    assert float(jnp.abs(g).max()) < 1e-3


def test_linreg_homogeneous_identical_truths():
    data = make_linreg(0, 4, 10, 50, homogeneous=True)
    assert np.allclose(data.t_n[0], data.t_n[1])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_dtypes():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        save(d, tree, metadata={"step": 42})
        out = restore(d, tree)
        from repro.checkpoint.store import metadata

        assert metadata(d)["step"] == 42
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out), strict=True):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_shape_mismatch_rejected():
    tree = {"a": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        save(d, tree)
        with pytest.raises(ValueError):
            restore(d, {"a": jnp.ones((3, 2))})


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_spec_divisibility_guard():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # divisible -> sharded
    assert resolve_spec(("embed", "mlp"), (64, 128), mesh) == P(None, "model")
    # not divisible -> replicated (phi3's 40 heads on the 16-way axis)
    assert resolve_spec(("heads", "head_dim"), (40, 64), mesh)[0] is None
    # smaller than axis -> replicated (qwen kv=2)
    assert resolve_spec(("kv_heads",), (2,), mesh) == P(None)


def test_resolve_spec_dp_axes():
    mesh = _FakeMesh({"pod": 2, "data": 4, "model": 16})
    spec = resolve_spec(("batch", "seq"), (32, 16), mesh,
                        dp_axes=("pod", "data"))
    assert spec == P(("pod", "data"), None)
    # batch not divisible by 8 -> replicated
    spec = resolve_spec(("batch",), (4,), mesh, dp_axes=("pod", "data"))
    assert spec == P(None)


# ---------------------------------------------------------------------------
# property: pipeline purity across jit boundaries
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_pipeline_pure_function_of_step(step):
    cfg = ModelConfig(vocab=64)
    pipe = TokenPipeline(cfg, 2, 8, seed=1)
    a = pipe.batch_at(step)["tokens"]
    b = jax.jit(lambda s: pipe.batch_at(s))(step)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
