"""RPL103 fixtures: Pallas kernel constraints (tiling, f64, tracer
ranges, program_id vs grid rank)."""
import textwrap

from tools.reprolint import lint_paths


def _lint(tmp_path, source):
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(source))
    viols, n_files = lint_paths(
        [str(f)], select=["RPL103"], repo_root=str(tmp_path)
    )
    assert n_files == 1
    return viols


def test_bad_tile_f64_and_program_id_flag(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import functools

        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        SUBLANES = 8
        BAD = (SUBLANES, 100)          # lane dim not %128

        def _kernel(x_ref, o_ref):
            i = pl.program_id(1)       # grid rank is 1
            o_ref[...] = x_ref[...].astype(jnp.float64)  # f64

        def run(x):
            spec = pl.BlockSpec(BAD, lambda i: (i, 0))
            return pl.pallas_call(
                _kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                grid=(x.shape[0] // SUBLANES,),
                in_specs=[spec],
                out_specs=spec,
            )(x)
        """,
    )
    msgs = " | ".join(v.message for v in viols)
    assert all(v.rule == "RPL103" for v in viols)
    assert "not a multiple of 128" in msgs
    assert "float64" in msgs
    assert "program_id(1)" in msgs


def test_tracer_range_loop_in_kernel_flags(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            n = x_ref[0, 0].astype(jnp.int32)
            acc = x_ref[...]
            for _ in range(n):         # tracer-dependent bound
                acc = acc * 2
            o_ref[...] = acc

        def run(x):
            return pl.pallas_call(
                _kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                grid=(1,),
            )(x)
        """,
    )
    assert [v.rule for v in viols] == ["RPL103"]
    assert "tracer-dependent range" in viols[0].message


def test_repo_idioms_stay_clean(tmp_path):
    # (8, 1024) tiles via module constants, degenerate (1, m)/(1, 1)
    # blocks, static keyword-only loop bounds, program_id(0): all legal.
    viols = _lint(
        tmp_path,
        """
        import functools

        import jax
        from jax.experimental import pallas as pl

        LANES = 1024
        SUBLANES = 8
        BLOCK = (SUBLANES, LANES)

        def _kernel(x_ref, o_ref, *, m):
            i = pl.program_id(0)
            acc = x_ref[...]
            for _ in range(m):         # m is static (partial-bound)
                acc = acc + 1.0
            o_ref[...] = acc

        def run(x, m):
            spec = pl.BlockSpec(BLOCK, lambda i: (i, 0))
            out = pl.BlockSpec((1, m), lambda i: (i, 0))
            scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
            return pl.pallas_call(
                functools.partial(_kernel, m=4),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                grid=(x.shape[0] // SUBLANES,),
                in_specs=[spec],
                out_specs=out,
            )(x), scalar
        """,
    )
    assert viols == []


def test_non_pallas_module_ignored(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax.numpy as jnp

        def host(x):
            return x.astype(jnp.float64)   # fine outside kernel modules
        """,
    )
    assert viols == []
