"""CI meta-tests (ISSUE 10 satellites).

* ``tools/check_ci_routing.py`` — the fast/slow test-lane partition
  guard: green on this repo's real workflow, and provably red on fixture
  workflows with an unrouted, double-routed, or phantom test file.
* ``benchmarks/run.py`` — the MODULES list and the module docstring must
  stay in sync (the drift this PR fixed for ``serve_bench``).
* ``tools/update_baselines.py`` — every bench it records a baseline for
  must be gated by a ``check_perf`` step in the workflow, and its
  post-write self-check must catch a truncated baseline.
"""
import json
import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)

from tools import check_ci_routing, check_perf, update_baselines  # noqa: E402

WORKFLOW_TEMPLATE = """\
name: ci
jobs:
  tier1-fast:
    steps:
      - name: fast
        run: >
          PYTHONPATH=src python -m pytest -x -q
{ignores}
  other-job:
    steps:
      - name: unrelated
        run: echo tests/test_red_herring.py
  tier1-slow:
    steps:
      - name: slow
        run: >
          PYTHONPATH=src python -m pytest -x -q
          {slow}
"""


def _fixture(tmp_path, ignores, slow, on_disk):
    tests = tmp_path / "tests"
    tests.mkdir()
    for name in on_disk:
        (tests / name).write_text("")
    wf = tmp_path / "ci.yml"
    wf.write_text(
        WORKFLOW_TEMPLATE.format(
            ignores="\n".join(f"          --ignore={p}" for p in ignores),
            slow=" ".join(slow),
        )
    )
    return check_ci_routing.check(str(wf), str(tests))


def test_real_workflow_is_green():
    assert (
        check_ci_routing.check(
            os.path.join(REPO_ROOT, ".github", "workflows", "ci.yml"),
            os.path.join(REPO_ROOT, "tests"),
        )
        == []
    )
    assert check_ci_routing.main([]) == 0


def test_partition_green_fixture(tmp_path):
    assert (
        _fixture(
            tmp_path,
            ignores=["tests/test_slow.py"],
            slow=["tests/test_slow.py"],
            on_disk=["test_slow.py", "test_fast.py"],
        )
        == []
    )


def test_unrouted_file_fails(tmp_path):
    """Ignored in fast but absent from slow: the file runs nowhere."""
    problems = _fixture(
        tmp_path,
        ignores=["tests/test_slow.py", "tests/test_orphan.py"],
        slow=["tests/test_slow.py"],
        on_disk=["test_slow.py", "test_orphan.py"],
    )
    assert any("test_orphan" in p and "no lane" in p for p in problems)


def test_double_routed_file_fails(tmp_path):
    """In slow but not ignored by fast: the file runs twice."""
    problems = _fixture(
        tmp_path,
        ignores=["tests/test_slow.py"],
        slow=["tests/test_slow.py", "tests/test_dup.py"],
        on_disk=["test_slow.py", "test_dup.py"],
    )
    assert any("test_dup" in p and "twice" in p for p in problems)


def test_phantom_file_fails(tmp_path):
    problems = _fixture(
        tmp_path,
        ignores=["tests/test_ghost.py"],
        slow=["tests/test_ghost.py"],
        on_disk=[],
    )
    assert any("does not exist" in p for p in problems)


def test_other_jobs_do_not_count(tmp_path):
    """A tests/ path mentioned in an unrelated job must not be treated
    as routed (the parser is scoped to the two tier1 job blocks)."""
    problems = _fixture(
        tmp_path,
        ignores=["tests/test_slow.py"],
        slow=["tests/test_slow.py"],
        on_disk=["test_slow.py"],
    )
    assert not any("red_herring" in p for p in problems)


def test_main_red_exit(tmp_path):
    _fixture(
        tmp_path,
        ignores=["tests/test_orphan.py"],
        slow=[],
        on_disk=["test_orphan.py"],
    )
    rc = check_ci_routing.main(
        ["--workflow", str(tmp_path / "ci.yml"),
         "--tests", str(tmp_path / "tests")]
    )
    assert rc == 1


# ---------------------------------------------------------------------------
# benchmarks/run.py docstring <-> MODULES sync
# ---------------------------------------------------------------------------


def test_run_modules_documented():
    from benchmarks import run as bench_run

    doc = bench_run.__doc__
    missing = [m for m in bench_run.MODULES if m not in doc]
    assert not missing, (
        f"benchmarks/run.py docstring is missing MODULES entries: {missing}"
    )


def test_run_modules_exist():
    for m in __import__("benchmarks.run", fromlist=["MODULES"]).MODULES:
        path = os.path.join(REPO_ROOT, "benchmarks", f"{m}.py")
        assert os.path.exists(path), f"MODULES lists {m} but {path} missing"


# ---------------------------------------------------------------------------
# update_baselines self-checks
# ---------------------------------------------------------------------------


def test_baselines_have_ci_gates():
    assert update_baselines.check_ci_gates() == []


def test_baseline_files_committed():
    for fname in update_baselines.BENCHES.values():
        path = os.path.join(REPO_ROOT, "benchmarks", "baselines", fname)
        assert os.path.exists(path), f"baseline {fname} not committed"


@pytest.mark.parametrize("fail_on_new", [False, True])
def test_check_perf_fail_on_new(tmp_path, fail_on_new):
    """A current row with no baseline entry passes by default and fails
    under --fail-on-new (the update_baselines self-check)."""
    meta = {"calib_us": 100.0, "jax": "x"}
    base = {"meta": meta, "rows": [
        {"name": "a", "us_per_call": 50.0, "derived": ""}]}
    cur = {"meta": meta, "rows": [
        {"name": "a", "us_per_call": 50.0, "derived": ""},
        {"name": "b_new", "us_per_call": 10.0, "derived": ""}]}
    bp, cp = tmp_path / "base.json", tmp_path / "cur.json"
    bp.write_text(json.dumps(base))
    cp.write_text(json.dumps(cur))
    argv = [str(cp), str(bp)] + (["--fail-on-new"] if fail_on_new else [])
    assert check_perf.main(argv) == (1 if fail_on_new else 0)
