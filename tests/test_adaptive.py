"""Adaptive-k acceptance tests (ISSUE 8, tier1-slow).

Two claims ride here:

* **Convergence** (seed-pinned Fig-3 linear regression): static Top-k at
  high compression plateaus at a strictly positive distance-to-optimum,
  while the adaptive RegTop-k controller — free to spend k up to a dense
  capacity when the error budget demands it — converges below tolerance
  on the same data, seed and learning rate.
* **Multi-worker off-switch**: the pinned-controller differential
  (``tests/test_controller.py`` runs it on one device) holds bit-for-bit
  on a real 4-worker shard_map mesh, where the controller's norms travel
  through psum/pmean.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, J = 20, 100
SEED = 42
STEPS = 2000
TOL = 1e-3


def run_sub(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=480,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def _gap_trace(kind, sparsity, adaptive=None):
    data = make_linreg(SEED, N, J, 500, homogeneous=False)
    cfg = SparsifierConfig(kind=kind, sparsity=sparsity, mu=16.0)
    sim = DistributedSim(
        linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2,
        adaptive_k=adaptive,
    )
    if adaptive is None:
        _, tr = sim.run(
            jnp.zeros(J), STEPS,
            trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
        )
        return np.asarray(tr), None
    _, tr = sim.run(
        jnp.zeros(J), STEPS,
        trace_state_fn=lambda s: (
            jnp.linalg.norm(s.theta - data.theta_star), s.ctrl.k
        ),
    )
    return np.asarray(tr[0]), np.asarray(tr[1])


def test_static_topk_plateaus_adaptive_regtopk_converges():
    """Paper Fig. 3 at S = 0.05 (20x compression): plain Top-k's optimality
    gap flatlines strictly above zero; the error-budget controller grows k
    whenever ||eps||/||g_agg|| overshoots and drives the gap below TOL."""
    static, _ = _gap_trace("topk", 0.05)
    # plateau: strictly positive, and no longer improving over the last
    # half of the run (the paper's high-compression stall)
    assert static[-1] > 0.2
    assert static[-1] > 0.8 * static[STEPS // 2]

    ctrl = comm.AdaptiveKController(budget=1.0, k_min=2, k_max=J)
    adaptive, ks = _gap_trace("regtopk", 0.05, adaptive=ctrl)
    assert adaptive[-1] < TOL, (
        f"adaptive gap {adaptive[-1]:.3e} above tolerance {TOL}"
    )
    # the win came from the controller actually moving k, within bounds
    assert ks.min() >= 2 and ks.max() <= J
    assert ks.max() > ks.min()
    # and strictly beats the static plateau on the same seed/data/lr
    assert adaptive[-1] < 1e-2 * static[-1]


def test_adaptive_equilibrates_to_budget():
    """A looser budget must equilibrate the smoothed error ratio near the
    budget itself (the closed loop's fixed point), holding k between the
    bounds rather than saturating — the distinguishing behavior of
    feedback control over a static schedule."""
    data = make_linreg(SEED, N, J, 500, homogeneous=False)
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.05, mu=16.0)
    ctrl = comm.AdaptiveKController(budget=3.0, k_min=2, k_max=J)
    sim = DistributedSim(
        linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2,
        adaptive_k=ctrl,
    )
    _, tr = sim.run(
        jnp.zeros(J), STEPS,
        trace_state_fn=lambda s: (s.ctrl.err_ratio, s.ctrl.k),
    )
    ratios, ks = np.asarray(tr[0]), np.asarray(tr[1])
    tail = ratios[STEPS // 2:]
    assert 0.5 * 3.0 < tail.mean() < 2.0 * 3.0
    assert 2 < ks[-1] < J  # interior equilibrium, not a bound


def test_spa_disabled_controller_bit_for_bit_multidevice():
    """Acceptance: disabled-controller trajectories are bit-for-bit
    unchanged in the shard_map runtime on a real 4-worker dp mesh —
    the controller's psum/pmean norm plumbing must not perturb a single
    ulp of the static path when k is pinned at the static value."""
    code = textwrap.dedent("""
        import json

        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P

        from repro import comm
        from repro.compat import make_mesh
        from repro.core.distributed import (
            DistConfig,
            LeafPlan,
            init_controller_state,
            init_sparsifier_state,
            make_sparsify_aggregate,
        )
        from repro.core.sparsify import SparsifierConfig

        mesh = make_mesh((4, 1), ("data", "model"))
        J, k = 256, 8
        grads = {"w": jnp.linspace(-1.0, 1.0, 4 * J).reshape(4, J)}
        plan = {"w": LeafPlan((J,), (J,), J, k, P(None), fused=False)}

        def rollout(adaptive):
            dist = DistConfig(
                sparsifier=SparsifierConfig(
                    kind="regtopk", sparsity=k / J, mu=4.0
                ),
                codec="coo_fp32", collective="sparse_allgather",
                dp_axes=("data",), adaptive_k=adaptive,
            )
            state, specs = init_sparsifier_state(
                plan, 4, mesh, ("data",), jnp.float32
            )
            spa = make_sparsify_aggregate(
                mesh, plan, {"w": P(None)}, specs, dist, 4
            )
            aggs = []
            with mesh:
                if adaptive is None:
                    for _ in range(5):
                        agg, state = jax.jit(spa)(grads, state)
                        aggs.append(np.asarray(agg["w"]))
                else:
                    ctrl, _ = init_controller_state(plan, dist)
                    for _ in range(5):
                        agg, state, ctrl = jax.jit(spa)(
                            grads, state, ctrl
                        )
                        aggs.append(np.asarray(agg["w"]))
            return aggs, state

        pinned = comm.AdaptiveKController(
            budget=1e9, k_min=k, k_max=k, hysteresis=0.0
        )
        a0, s0 = rollout(None)
        a1, s1 = rollout(pinned)
        agg_same = all(
            bool(np.array_equal(x, y))
            for x, y in zip(a0, a1, strict=True)
        )
        st_same = all(
            bool(np.array_equal(np.asarray(x), np.asarray(y)))
            for x, y in zip(
                jax.tree.leaves(s0), jax.tree.leaves(s1), strict=True
            )
        )
        print(json.dumps({"agg_same": agg_same, "st_same": st_same}))
    """)
    res = run_sub(code, devices=4)
    assert res["agg_same"] and res["st_same"], res


def test_adaptive_spa_multidevice_adapts_and_compiles_once():
    """4-worker adaptive round: k moves under a tight budget, controller
    state stays replicated-consistent, and the loop compiles once."""
    code = textwrap.dedent("""
        import json

        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro import comm
        from repro.compat import make_mesh
        from repro.core.distributed import (
            DistConfig,
            LeafPlan,
            init_controller_state,
            init_sparsifier_state,
            make_sparsify_aggregate,
        )
        from repro.core.sparsify import SparsifierConfig

        mesh = make_mesh((4, 1), ("data", "model"))
        J = 256
        dist = DistConfig(
            sparsifier=SparsifierConfig(
                kind="regtopk", sparsity=8 / J, mu=4.0
            ),
            codec="coo_fp32", collective="sparse_allgather",
            dp_axes=("data",),
            adaptive_k=comm.AdaptiveKController(
                budget=0.01, k_min=2, k_max=64
            ),
        )
        plan = {"w": LeafPlan((J,), (J,), J, 64, P(None), fused=False)}
        state, specs = init_sparsifier_state(
            plan, 4, mesh, ("data",), jnp.float32
        )
        ctrl, _ = init_controller_state(plan, dist)
        spa = make_sparsify_aggregate(
            mesh, plan, {"w": P(None)}, specs, dist, 4
        )
        calls = {"n": 0}

        def counted(g, s, c):
            calls["n"] += 1
            return spa(g, s, c)

        step = jax.jit(counted)
        grads = {"w": jnp.linspace(-1.0, 1.0, 4 * J).reshape(4, J)}
        ks = []
        with mesh:
            for _ in range(6):
                agg, state, ctrl = step(grads, state, ctrl)
                ks.append(int(ctrl["w"].k))
        jax.block_until_ready(agg)
        print(json.dumps({
            "traces": calls["n"], "ks": ks,
            "t": int(state["w"].t[0]),
        }))
    """)
    res = run_sub(code, devices=4)
    assert res["traces"] == 1, res
    assert len(set(res["ks"])) > 1, res
    assert res["t"] == 6
