"""RPL101 fixtures: tracer-unsafe Python control flow in traced functions.

True positives must flag; the clean fixtures encode the idioms the repo
actually relies on (config branching inside shard_map bodies, shape/dtype
branches, `is None` plumbing) and must stay silent.
"""
import textwrap

from tools.reprolint import lint_paths


def _lint(tmp_path, source, select=("RPL101",)):
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(source))
    viols, n_files = lint_paths(
        [str(f)], select=list(select), repo_root=str(tmp_path)
    )
    assert n_files == 1
    return viols


def test_branch_on_array_param_flags(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax

        @jax.jit
        def f(x: jax.Array):
            if x > 0:
                return x
            return -x
        """,
    )
    assert [v.rule for v in viols] == ["RPL101"]
    assert "if" in viols[0].message and "'f'" in viols[0].message


def test_while_and_assert_in_shard_map_body_flag(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax
        from jax.experimental.shard_map import shard_map

        def build(mesh, specs):
            def body(g: jax.Array):
                assert g.sum() > 0
                while g.mean() > 1:
                    g = g * 0.5
                return g
            return shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs)
        """,
    )
    assert sorted({v.rule for v in viols}) == ["RPL101"]
    assert len(viols) == 2  # the assert and the while


def test_taint_through_array_annotated_state_field_flags(tmp_path):
    # the repo-aware pre-pass: ``st.t`` taints because SomeState.t is
    # annotated jax.Array, even though ``st`` itself is untyped.
    viols = _lint(
        tmp_path,
        """
        import jax
        from typing import NamedTuple

        class SomeState(NamedTuple):
            t: jax.Array

        @jax.jit
        def step(st):
            if st.t > 0:
                return st
            return st
        """,
    )
    assert [v.rule for v in viols] == ["RPL101"]


def test_config_and_shape_branches_stay_clean(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x: jax.Array, cfg=None):
            if x.ndim == 2:                      # static: shape attr
                x = x.reshape(-1)
            if cfg is not None and cfg.kind == "regtopk":  # config dispatch
                x = x * 2.0
            k = max(1, int(0.01 * x.shape[0]))   # concretizing builtins
            if k > x.shape[0]:
                k = x.shape[0]
            return jnp.where(x > 0, x, 0.0)      # value branch done right
        """,
    )
    assert viols == []


def test_untraced_function_branches_stay_clean(tmp_path):
    viols = _lint(
        tmp_path,
        """
        import numpy as np

        def host_side(x):
            if np.asarray(x).sum() > 0:
                return x
            return -x
        """,
    )
    assert viols == []
