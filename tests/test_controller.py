"""Property + differential tests for the adaptive-k controller (ISSUE 8).

Covers, per the issue's satellite checklist:

* control-law properties (hypothesis via ``_hyp``): planned k always lands
  in the resolved ``[k_min, k_max]``; k is monotone **non-decreasing** in
  the smoothed error ratio — equivalently non-increasing in the
  error-budget slack ``budget - err_ratio``; the hysteresis dead band
  keeps k still;
* ``parse_adaptive_k`` accepts/rejects the documented CLI grammar;
* the off-switch differential: a *pinned* controller
  (``k_min == k_max == static k``) is bit-for-bit the historical static-k
  trajectory, in both :class:`repro.core.DistributedSim` and the
  ``make_sparsify_aggregate`` shard_map runtime, over randomized configs;
* retrace guards (the ``test_guards`` counting idiom): the adaptive round
  compiles exactly once even while k moves — k is a dynamic operand, the
  payload capacity is the static shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.comm import AdaptiveKController, parse_adaptive_k
from repro.core import (
    DistributedSim,
    SparsifierConfig,
    exact_topk_mask,
    exact_topk_mask_dynamic,
    sparsity_to_k,
)

N, J = 4, 64
BOUNDS = (2, 32)


def _ctrl(**kw):
    kw.setdefault("budget", 0.1)
    return AdaptiveKController(**kw)


def _grad_fn(seed: int):
    """Deterministic heterogeneous quadratic: worker w's gradient of
    0.5 * ||sqrt(A_w) theta - b_w||^2 elementwise."""
    key = jax.random.PRNGKey(seed)
    A = jax.random.uniform(key, (N, J), minval=0.5, maxval=1.5)
    b = jax.random.normal(jax.random.fold_in(key, 1), (N, J))

    def gf(theta, w):
        return A[w] * theta - b[w]

    return gf


# ---------------------------------------------------------------------------
# control-law properties
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    length=st.integers(min_value=1, max_value=5000),
    lo=st.floats(min_value=1e-3, max_value=0.4),
    span=st.floats(min_value=0.0, max_value=0.5),
)
def test_bounds_fractions_resolve_ordered_within_length(length, lo, span):
    c = _ctrl(k_min=lo, k_max=min(lo + span, 0.999))
    kmin, kmax = c.bounds(length)
    assert 1 <= kmin <= kmax <= length


@settings(max_examples=30, deadline=None)
@given(
    length=st.integers(min_value=1, max_value=5000),
    lo=st.integers(min_value=1, max_value=64),
    span=st.integers(min_value=0, max_value=512),
)
def test_bounds_absolute_clip_to_length(length, lo, span):
    c = _ctrl(k_min=lo, k_max=lo + span)
    kmin, kmax = c.bounds(length)
    assert 1 <= kmin <= kmax <= length
    assert kmax <= lo + span


@settings(max_examples=40, deadline=None)
@given(
    budget=st.floats(min_value=1e-3, max_value=10.0),
    ratio=st.floats(min_value=0.0, max_value=100.0),
    k0=st.integers(min_value=BOUNDS[0], max_value=BOUNDS[1]),
    hyst=st.floats(min_value=0.0, max_value=1.0),
    gain=st.floats(min_value=1.01, max_value=8.0),
)
def test_plan_k_always_within_bounds(budget, ratio, k0, hyst, gain):
    c = _ctrl(budget=budget, hysteresis=hyst, gain=gain)
    k = int(c.plan_k(jnp.asarray(ratio), jnp.asarray(k0), *BOUNDS))
    assert BOUNDS[0] <= k <= BOUNDS[1]


@settings(max_examples=40, deadline=None)
@given(
    budget=st.floats(min_value=1e-2, max_value=5.0),
    r1=st.floats(min_value=0.0, max_value=20.0),
    r2=st.floats(min_value=0.0, max_value=20.0),
    k0=st.integers(min_value=BOUNDS[0], max_value=BOUNDS[1]),
    hyst=st.floats(min_value=0.0, max_value=0.5),
)
def test_plan_k_monotone_in_budget_slack(budget, r1, r2, k0, hyst):
    """More error-budget slack (budget - ratio) never *raises* k: the
    planned k is monotone non-decreasing in the error ratio."""
    c = _ctrl(budget=budget, hysteresis=hyst)
    lo_r, hi_r = sorted((r1, r2))
    k_lo = int(c.plan_k(jnp.asarray(lo_r), jnp.asarray(k0), *BOUNDS))
    k_hi = int(c.plan_k(jnp.asarray(hi_r), jnp.asarray(k0), *BOUNDS))
    assert k_lo <= k_hi


@settings(max_examples=30, deadline=None)
@given(
    budget=st.floats(min_value=1e-2, max_value=5.0),
    hyst=st.floats(min_value=1e-3, max_value=0.5),
    k0=st.integers(min_value=BOUNDS[0], max_value=BOUNDS[1]),
    u=st.floats(min_value=-1.0, max_value=1.0),
)
def test_hysteresis_dead_band_keeps_k(budget, hyst, k0, u):
    """Any pressure inside [1 - h, 1 + h] keeps the previous k."""
    c = _ctrl(budget=budget, hysteresis=hyst)
    ratio = budget * (1.0 + 0.999 * hyst * u)
    k = int(c.plan_k(jnp.asarray(ratio), jnp.asarray(k0), *BOUNDS))
    assert k == k0


def test_observe_seeds_then_discounts():
    c = _ctrl(budget=1.0, momentum=0.8, hysteresis=0.0)
    s = c.init(8, *BOUNDS)
    s = c.observe(s, jnp.asarray(3.0), jnp.asarray(1.0), k_min=2, k_max=32)
    assert float(s.err_ratio) == pytest.approx(3.0)  # t == 0 seeds raw
    s = c.observe(s, jnp.asarray(1.0), jnp.asarray(1.0), k_min=2, k_max=32)
    assert float(s.err_ratio) == pytest.approx(0.8 * 3.0 + 0.2 * 1.0)
    assert int(s.t) == 2


def test_config_validation():
    for bad in (
        dict(budget=0.0),
        dict(budget=1.0, momentum=1.0),
        dict(budget=1.0, hysteresis=-0.1),
        dict(budget=1.0, gain=1.0),
        dict(budget=1.0, k_min=0.0),
        dict(budget=1.0, k_min=0.5, k_max=0.25),
        dict(budget=1.0, k_min=64, k_max=8),
    ):
        with pytest.raises(ValueError):
            AdaptiveKController(**bad)
    # mixed-kind bounds resolve per leaf; ordering is checked there
    c = AdaptiveKController(budget=1.0, k_min=0.5, k_max=4)
    with pytest.raises(ValueError):
        c.bounds(100)  # 50 > 4


def test_parse_adaptive_k():
    c = parse_adaptive_k("0.25")
    assert c.budget == 0.25
    c = parse_adaptive_k(" 0.1 , 4 , 0.5 ")
    assert (c.budget, c.k_min, c.k_max) == (0.1, 4.0, 0.5)
    for bad in ("", "0.1,4", "0.1,4,8,16", "abc", "0.1,x,8"):
        with pytest.raises(ValueError):
            parse_adaptive_k(bad)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    k=st.integers(min_value=0, max_value=J),
)
def test_dynamic_mask_matches_static_at_capacity(seed, k):
    score = jnp.abs(
        jax.random.normal(jax.random.PRNGKey(seed), (J,))
    ) * (jax.random.uniform(jax.random.PRNGKey(seed + 1), (J,)) > 0.2)
    static = exact_topk_mask(score, k)
    dyn = exact_topk_mask_dynamic(score, jnp.asarray(k), k)
    assert np.array_equal(np.asarray(static), np.asarray(dyn))
    # below capacity: cardinality is min(k_dyn, live entries), a subset
    # of the capacity winners
    if k >= 2:
        part = exact_topk_mask_dynamic(score, jnp.asarray(k // 2), k)
        assert float(part.sum()) <= min(k // 2, int((score > 0).sum()))
        assert bool(jnp.all(static - part >= 0))


# ---------------------------------------------------------------------------
# off-switch differential: pinned controller == static path, bit-for-bit
# ---------------------------------------------------------------------------
def _run_sim(seed, kind, sparsity, collective, codec, adaptive, steps=4):
    cfg = SparsifierConfig(kind=kind, sparsity=sparsity, mu=4.0)
    sim = DistributedSim(
        _grad_fn(seed), N, J, cfg, learning_rate=1e-2,
        collective=collective, codec=codec, adaptive_k=adaptive,
    )
    final, trace = sim.run(jnp.ones(J), steps)
    return final, np.asarray(trace)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    kind=st.sampled_from(["topk", "regtopk"]),
    sparsity=st.sampled_from([0.05, 0.125, 0.3]),
    collective=st.sampled_from(["dense_allreduce", "sparse_allgather"]),
    codec=st.sampled_from(["coo_fp32", "coo_q8"]),
)
def test_sim_disabled_controller_is_bit_for_bit(
    seed, kind, sparsity, collective, codec
):
    """adaptive_k=None vs a pinned controller (k_min == k_max == the
    static k, budget huge): the dynamic-k machinery must be a no-op —
    every SimState leaf identical, every round."""
    k_st = sparsity_to_k(J, sparsity)
    pinned = AdaptiveKController(
        budget=1e9, k_min=k_st, k_max=k_st, hysteresis=0.0
    )
    f0, tr0 = _run_sim(seed, kind, sparsity, collective, codec, None)
    f1, tr1 = _run_sim(seed, kind, sparsity, collective, codec, pinned)
    assert np.array_equal(tr0, tr1)
    for a, b in zip(
        jax.tree.leaves(f0._replace(ctrl=None)),
        jax.tree.leaves(f1._replace(ctrl=None)),
        strict=True,
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(f1.ctrl.k) == k_st  # pinned: never moved


@pytest.mark.parametrize("kind", ["topk", "regtopk"])
def test_spa_disabled_controller_is_bit_for_bit(kind):
    """Same differential through the shard_map runtime (single-device
    mesh in-process; the multi-worker mesh variant rides tier1-slow)."""
    from repro.compat import make_mesh
    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        init_controller_state,
        init_sparsifier_state,
        make_sparsify_aggregate,
    )

    mesh = make_mesh((1, 1), ("data", "model"))
    k_st = sparsity_to_k(J, 0.125)
    grads = {"w": jnp.linspace(-1.0, 1.0, J).reshape(1, J)}
    plan = {"w": LeafPlan((J,), (J,), J, k_st, P(None), fused=False)}

    def rollout(adaptive):
        dist = DistConfig(
            sparsifier=SparsifierConfig(kind=kind, sparsity=0.125, mu=4.0),
            codec="coo_fp32", collective="sparse_allgather",
            dp_axes=("data",), adaptive_k=adaptive,
        )
        state, specs = init_sparsifier_state(
            plan, 1, mesh, ("data",), jnp.float32
        )
        spa = make_sparsify_aggregate(
            mesh, plan, {"w": P(None)}, specs, dist, 1
        )
        aggs = []
        step = jax.jit(spa)
        with mesh:
            if adaptive is None:
                for _ in range(4):
                    agg, state = step(grads, state)
                    aggs.append(np.asarray(agg["w"]))
            else:
                ctrl, _ = init_controller_state(plan, dist)
                for _ in range(4):
                    agg, state, ctrl = step(grads, state, ctrl)
                    aggs.append(np.asarray(agg["w"]))
        return aggs, state

    pinned = AdaptiveKController(
        budget=1e9, k_min=k_st, k_max=k_st, hysteresis=0.0
    )
    aggs0, st0 = rollout(None)
    aggs1, st1 = rollout(pinned)
    for a, b in zip(aggs0, aggs1, strict=True):
        assert np.array_equal(a, b)
    for a, b in zip(
        jax.tree.leaves(st0), jax.tree.leaves(st1), strict=True
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# retrace guards: k moves, the compiled round does not
# ---------------------------------------------------------------------------
def _counting(fn):
    calls = {"n": 0}

    def wrapper(*args, **kwargs):
        calls["n"] += 1
        return fn(*args, **kwargs)

    return wrapper, calls


def test_adaptive_sim_round_compiles_once():
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.125, mu=4.0)
    sim = DistributedSim(
        _grad_fn(3), N, J, cfg, learning_rate=1e-2,
        collective="sparse_allgather",
        adaptive_k=AdaptiveKController(budget=0.01, k_min=2, k_max=32),
    )
    counted, calls = _counting(sim.step_fn)
    step = jax.jit(counted)
    state = sim.init(jnp.ones(J))
    ks = []
    for _ in range(6):
        state, _ = step(state)
        ks.append(int(state.ctrl.k))
    assert calls["n"] == 1, f"adaptive round retraced: {calls['n']} traces"
    assert len(set(ks)) > 1, f"controller never moved k: {ks}"


def test_adaptive_spa_round_compiles_once():
    from repro.compat import make_mesh
    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        init_controller_state,
        init_sparsifier_state,
        make_sparsify_aggregate,
    )

    mesh = make_mesh((1, 1), ("data", "model"))
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.125, mu=4.0),
        codec="coo_fp32", collective="sparse_allgather",
        dp_axes=("data",),
        adaptive_k=AdaptiveKController(budget=0.01, k_min=2, k_max=32),
    )
    plan = {"w": LeafPlan((J,), (J,), J, 32, P(None), fused=False)}
    state, specs = init_sparsifier_state(plan, 1, mesh, ("data",), jnp.float32)
    ctrl, _ = init_controller_state(plan, dist)
    spa = make_sparsify_aggregate(mesh, plan, {"w": P(None)}, specs, dist, 1)
    counted, calls = _counting(spa)
    step = jax.jit(counted)
    grads = {"w": jnp.linspace(-1.0, 1.0, J).reshape(1, J)}
    ks = []
    with mesh:
        for _ in range(6):
            agg, state, ctrl = step(grads, state, ctrl)
            ks.append(int(ctrl["w"].k))
    jax.block_until_ready(agg)
    assert calls["n"] == 1, f"adaptive shard_map retraced: {calls['n']}"
    assert len(set(ks)) > 1, f"controller never moved k: {ks}"


def test_capacity_mismatch_fails_fast():
    """A plan whose leaf capacity is not the controller's k_max must be
    rejected at build time, not deep inside the traced round."""
    from repro.compat import make_mesh
    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        init_sparsifier_state,
        make_sparsify_aggregate,
    )

    mesh = make_mesh((1, 1), ("data", "model"))
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.125),
        dp_axes=("data",),
        adaptive_k=AdaptiveKController(budget=0.1, k_min=2, k_max=32),
    )
    plan = {"w": LeafPlan((J,), (J,), J, 8, P(None), fused=False)}  # k != 32
    _, specs = init_sparsifier_state(plan, 1, mesh, ("data",), jnp.float32)
    with pytest.raises(ValueError, match="capacity mismatch"):
        make_sparsify_aggregate(mesh, plan, {"w": P(None)}, specs, dist, 1)


def test_adaptive_rejects_unsupported_kinds():
    cfg = SparsifierConfig(kind="dgc", sparsity=0.125)
    with pytest.raises(ValueError, match="topk"):
        DistributedSim(
            _grad_fn(0), N, J, cfg,
            adaptive_k=AdaptiveKController(budget=0.1),
        )
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.125,
                           selector="threshold")
    with pytest.raises(ValueError, match="exact"):
        DistributedSim(
            _grad_fn(0), N, J, cfg,
            adaptive_k=AdaptiveKController(budget=0.1),
        )
