"""Docs subsystem checks (ISSUE 3 satellites).

The `docs/` pages must exist, their links/anchors/file references must
resolve (tools/check_docs.py — the same checker the CI `docs` job runs),
and every public `repro.comm` module-level function must carry a doctest
example (verified by `pytest --doctest-modules src/repro/comm` in CI;
here we enforce presence so drift fails tier-1 too).
"""
import importlib
import inspect
import os
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # the module under test: tools/check_docs.py


def test_docs_pages_exist():
    for page in ("docs/paper_map.md", "docs/comm.md"):
        assert os.path.exists(os.path.join(REPO, page)), f"{page} missing"


@pytest.mark.parametrize(
    "page", ["docs/paper_map.md", "docs/comm.md", "README.md"]
)
def test_docs_links_and_paths_resolve(page):
    errors = check_docs.check_file(os.path.join(REPO, page))
    assert not errors, "\n".join(errors)


def test_check_docs_catches_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text(
        "[x](nonexistent.md) and [y](#no-such-heading)\n"
        "`src/repro/comm/nonexistent.py` and "
        "`src/repro/comm/cost.py::not_a_function`\n"
    )
    errors = check_docs.check_file(str(bad))
    assert len(errors) == 4


def test_github_slugs():
    assert check_docs.github_slug("The `repro.comm` subsystem") == (
        "the-reprocomm-subsystem"
    )
    assert check_docs.github_slug("Per-axis decomposition: `LinkTopo`") == (
        "per-axis-decomposition-linktopo"
    )


COMM_MODULES = [
    "repro.comm.codec",
    "repro.comm.collectives",
    "repro.comm.cost",
    "repro.comm.autotune",
    "repro.comm.calibrate",
    "repro.comm.participation",
    "repro.comm.controller",
    "repro.comm.overlap",
]


@pytest.mark.parametrize("modname", COMM_MODULES)
def test_public_comm_functions_have_doctests(modname):
    mod = importlib.import_module(modname)
    missing = []
    for name, fn in vars(mod).items():
        if name.startswith("_") or not inspect.isfunction(fn):
            continue
        if fn.__module__ != modname:
            continue  # re-export, owned elsewhere
        if ">>>" not in (inspect.getdoc(fn) or ""):
            missing.append(name)
    assert not missing, (
        f"{modname}: public functions without doctest examples: {missing}"
    )


def test_symbol_level_dotted_references(tmp_path):
    """ISSUE 7 satellite: dotted repro.* spans resolve via importlib —
    and drifted ones fail."""
    good = tmp_path / "good.md"
    good.write_text(
        "Use `repro.comm.cost.predict` with `repro.comm.Participation`;\n"
        "`repro.comm.autotune.choose_leaf(fastpath=...)` plans leaves.\n"
    )
    assert check_docs.check_file(str(good)) == []

    drifted = tmp_path / "drifted.md"
    drifted.write_text(
        "Call `repro.comm.cost.predict_bytes` (renamed long ago) and\n"
        "see `repro.core.not_a_module` for details.\n"
    )
    errors = check_docs.check_file(str(drifted))
    assert len(errors) == 2
    assert all("does not resolve" in e for e in errors)


def test_dotted_check_skips_paths_and_fences(tmp_path):
    md = tmp_path / "mixed.md"
    md.write_text(
        "The file `src/repro/comm/cost.py` is a path, not a symbol.\n"
        "```python\nimport repro.bogus.example  # illustrative only\n```\n"
    )
    assert check_docs.check_file(str(md)) == []
