"""Per-leaf (codec x collective) auto-planning tests (ISSUE 2 tentpole).

Planner unit behaviour (admissibility, determinism, optimality), the
DistConfig/DistributedSim "auto" threading, and the calibrate fit.
"""
import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _hyp import given, settings, st
from repro import comm
from repro.comm.autotune import candidate_pairs, choose_leaf, plan_tree
from repro.comm.calibrate import Sample, fit_alpha_beta
from repro.core import DistributedSim, SparsifierConfig
from repro.core.distributed import DistConfig, LeafPlan, build_plan, leaf_wire
from repro.core.selectors import sparsity_to_k

LOSSLESS = sorted(
    n for n in comm.CODECS if comm.get_codec(n).lossless
)


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------
def test_candidates_exclude_lossy_by_default():
    pairs = candidate_pairs()
    assert all(comm.get_codec(c).lossless for c, _ in pairs)
    lossy = candidate_pairs(allow_lossy=True)
    assert any(c == "coo_q8" for c, _ in lossy)


def test_candidates_dense_is_codec_independent():
    pairs = candidate_pairs()
    dense = [(c, s) for c, s in pairs if s == "dense_allreduce"]
    assert dense == [("coo_fp32", "dense_allreduce")]


def test_candidates_respect_restrictions_and_fail_fast():
    pairs = candidate_pairs(codecs=["bitmap_dense"],
                            collectives=["sparse_allgather"])
    assert pairs == (("bitmap_dense", "sparse_allgather"),)
    with pytest.raises(ValueError, match="unknown codec"):
        candidate_pairs(codecs=["bogus"])
    with pytest.raises(ValueError, match="unknown collective"):
        candidate_pairs(collectives=["bogus"])
    with pytest.raises(ValueError, match="no admissible"):
        candidate_pairs(codecs=["coo_q8"],
                        collectives=["sparse_allgather"])


# ---------------------------------------------------------------------------
# choose_leaf: the picks the ISSUE motivates
# ---------------------------------------------------------------------------
def test_tiny_leaf_picks_delta_indices():
    d = choose_leaf(64, 2, (8,))
    assert d.codec == "coo_idx_delta"  # int8 deltas on L < 2^7


def test_dense_ish_leaf_picks_bitmap():
    d = choose_leaf(65536, 65536 // 8, (8,))  # S = 1/8 > 1/32
    assert d.codec == "bitmap_dense"


def test_hierarchical_only_when_outer_axes_pay_off():
    # single-axis mesh: hierarchical degenerates to the dense pattern and
    # can never win the tie-break against dense_allreduce
    for L, k in ((64, 2), (65536, 8192), (262144, 262)):
        assert choose_leaf(L, k, (8,)).collective != "hierarchical"
    # multi-axis mesh, latency-aware (default) model: hierarchical wins by
    # cutting messages — (b-1) + 2(a-1) vs allgather's ab-1
    assert choose_leaf(100_000, 100, (4, 8)).collective == "hierarchical"
    # uniform bandwidth-only link (alpha=0): hierarchical sits exactly on
    # the min(dense, allgather) byte envelope (pb < 8L/n -> dense wins,
    # pb > 8L/n -> allgather wins) and is never *strictly* better — beating
    # both needs the latency term or per-level link models (ROADMAP).
    bw = comm.AlphaBeta(alpha=0.0, beta=1e-11)
    assert choose_leaf(100_000, 100, (2, 8), bw).collective == (
        "sparse_allgather"
    )
    assert choose_leaf(100_000, 25_000, (2, 8), bw).collective == (
        "dense_allreduce"
    )


def test_choose_leaf_is_deterministic_and_seconds_optimal():
    for L, k, dp in ((100, 5, (4,)), (4096, 41, (2, 8)), (65536, 8192, (16,))):
        d1 = choose_leaf(L, k, dp)
        d2 = choose_leaf(L, k, dp)
        assert (d1.codec, d1.collective) == (d2.codec, d2.collective)
        for c, s in candidate_pairs():
            est = comm.predict(c, s, L, k, dp)
            assert d1.cost.seconds <= est.seconds * (1 + 1e-12)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_auto_never_worse_than_any_fixed_codec(seed):
    """Auto is seconds-optimal against every fixed-codec plan, and
    byte-optimal against every fixed codec under its chosen collective
    (same collective -> same message count -> seconds order is byte
    order)."""
    rng = np.random.RandomState(seed)
    L = int(rng.randint(8, 1_000_000))
    k = int(rng.randint(1, max(L // 4, 2)))
    dp = [(4,), (8,), (16,), (2, 8), (4, 8)][seed % 5]
    auto = choose_leaf(L, k, dp)
    for c in LOSSLESS:
        fixed = choose_leaf(L, k, dp, codecs=[c])
        assert auto.cost.seconds <= fixed.cost.seconds * (1 + 1e-12)
        same_coll = choose_leaf(
            L, k, dp, codecs=[c], collectives=[auto.collective]
        )
        assert auto.cost.bytes_on_wire <= same_coll.cost.bytes_on_wire


def test_word_bytes_scales_dense_terms():
    full = choose_leaf(4096, 4, (8,), collectives=["dense_allreduce"])
    half = choose_leaf(
        4096, 4, (8,), collectives=["dense_allreduce"], word_bytes=2
    )
    assert half.cost.bytes_on_wire * 2 == full.cost.bytes_on_wire


def test_word_bytes_does_not_discount_payload_strategies():
    """Payload strategies decode to f32 before any intra-axis psum, so a
    bf16 state dtype (word_bytes=2) must only cheapen the dense_allreduce
    wire — pricing hierarchical's intra term at 2 B/word would make the
    planner disagree with comm_round_bytes' accounting."""
    for coll in ("sparse_allgather", "hierarchical"):
        a4 = choose_leaf(100_000, 100, (4, 8), collectives=[coll])
        a2 = choose_leaf(
            100_000, 100, (4, 8), collectives=[coll], word_bytes=2
        )
        assert a4.cost == a2.cost


# ---------------------------------------------------------------------------
# plan_tree
# ---------------------------------------------------------------------------
def _leaf(L, S):
    return LeafPlan((L,), (L,), L, sparsity_to_k(L, S), P(None))


def test_plan_tree_heterogeneous_picks_and_totals():
    tree = {"bias": _leaf(64, 0.05), "embed": _leaf(65536, 0.125)}
    cp = plan_tree(tree, (8,))
    assert cp.decisions["bias"].codec == "coo_idx_delta"
    assert cp.decisions["embed"].codec == "bitmap_dense"
    assert cp.total_bytes == sum(
        d.cost.bytes_on_wire for d in cp.decisions.values()
    )
    assert cp.total_seconds == pytest.approx(
        sum(d.cost.seconds for d in cp.decisions.values())
    )
    # per-leaf freedom beats the best single codec on the mixed tree
    best_single = min(
        plan_tree(tree, (8,), codecs=[c]).total_bytes for c in LOSSLESS
    )
    assert cp.total_bytes < best_single


# ---------------------------------------------------------------------------
# DistConfig / build_plan threading
# ---------------------------------------------------------------------------
class _Mesh:
    shape: ClassVar[dict] = {"data": 8}


def _shapes(tree):
    return jax.tree.map(
        lambda L: jax.ShapeDtypeStruct((L,), jnp.float32), tree
    )


def test_build_plan_auto_fills_per_leaf_choices():
    shapes = _shapes({"bias": 64, "embed": 65536})
    specs = {"bias": P(None), "embed": P(None)}
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.05),
        codec="auto", collective="auto",
    )
    plan = build_plan(shapes, specs, _Mesh(), 0.05, dist)
    assert plan["bias"].codec == "coo_idx_delta"
    assert plan["embed"].codec == "bitmap_dense"
    assert leaf_wire(plan["embed"], dist) == (
        "bitmap_dense", plan["embed"].collective
    )
    # fixed config leaves the per-leaf fields unset -> global resolution
    fixed = DistConfig(codec="coo_fp32", collective="sparse_allgather")
    plan2 = build_plan(shapes, specs, _Mesh(), 0.05, fixed)
    assert plan2["bias"].codec is None
    assert leaf_wire(plan2["bias"], fixed) == (
        "coo_fp32", "sparse_allgather"
    )


def test_leaf_wire_rejects_unresolved_auto():
    dist = DistConfig(codec="auto")
    p = _leaf(64, 0.05)  # built without dist -> no per-leaf codec
    with pytest.raises(ValueError, match="auto"):
        leaf_wire(p, dist)


@pytest.mark.parametrize("kind", ["none", "hard_threshold"])
def test_auto_forces_dense_for_variable_cardinality_kinds(kind):
    shapes = _shapes({"w": 4096})
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind=kind, sparsity=0.05),
        codec="auto", collective="auto",
    )
    plan = build_plan(shapes, {"w": P(None)}, _Mesh(), 0.05, dist)
    assert plan["w"].collective == "dense_allreduce"


def test_comm_round_bytes_sums_per_leaf_choices():
    from repro.core.distributed import comm_round_bytes

    shapes = _shapes({"bias": 64, "embed": 65536})
    specs = {"bias": P(None), "embed": P(None)}
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.125),
        codec="auto", collective="auto",
    )
    plan = build_plan(shapes, specs, _Mesh(), 0.125, dist)
    pred, meas = comm_round_bytes(plan, dist, _Mesh())
    # per-leaf sums match re-deriving each leaf's own prediction
    want = 0
    for p in (plan["bias"], plan["embed"]):
        want += comm.predicted_bytes(
            p.codec, p.collective, p.local_len, p.k, [8]
        )
    assert pred == want
    assert meas <= pred * 1.05


# ---------------------------------------------------------------------------
# simulator auto mirrors dense numerics
# ---------------------------------------------------------------------------
def _toy():
    x = jnp.array([[100.0, 1.0], [-100.0, 1.0]])

    def grad_fn(theta, n):
        xn = x[n]
        e = jnp.exp(-jnp.dot(theta, xn))
        return -e * xn / (1 + e)

    return grad_fn


def test_simulator_auto_resolves_and_matches_dense():
    grad_fn = _toy()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0)
    sim = DistributedSim(
        grad_fn, 2, 2, cfg, learning_rate=0.9,
        codec="auto", collective="auto",
    )
    assert sim.codec in comm.CODECS and comm.get_codec(sim.codec).lossless
    assert sim.resolved_collective in comm.COLLECTIVES
    ref = DistributedSim(grad_fn, 2, 2, cfg, learning_rate=0.9)
    fin, _ = sim.run(jnp.array([0.0, 1.0]), 30)
    fin_ref, _ = ref.run(jnp.array([0.0, 1.0]), 30)
    np.testing.assert_allclose(
        np.asarray(fin.theta), np.asarray(fin_ref.theta), rtol=1e-5
    )


def test_simulator_auto_hard_threshold_stays_dense():
    grad_fn = _toy()
    cfg = SparsifierConfig(kind="hard_threshold", threshold=0.1)
    sim = DistributedSim(grad_fn, 2, 2, cfg, codec="auto", collective="auto")
    assert sim.resolved_collective == "dense_allreduce"
    # an explicitly requested payload collective is NOT silently overridden
    # — it raises exactly like the fixed-codec path does
    with pytest.raises(ValueError, match="hard_threshold"):
        DistributedSim(
            grad_fn, 2, 2, cfg, codec="auto", collective="sparse_allgather"
        )


# ---------------------------------------------------------------------------
# LinkTopo: per-mesh-axis link classes (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------
def test_uniform_linktopo_matches_alphabeta_bitforbit():
    """A LinkTopo with identical per-axis links must reproduce the scalar
    AlphaBeta predictions exactly — bytes, messages, AND seconds (the
    uniform path keeps the historical fp operation order)."""
    scalar = comm.AlphaBeta(alpha=2.3e-5, beta=3.7e-11)
    for dp in ((8,), (2, 4), (2, 4, 8)):
        topo = comm.LinkTopo.uniform(scalar, len(dp))
        for cname in sorted(comm.CODECS):
            for sname in sorted(comm.COLLECTIVES):
                for L, k in ((64, 2), (4096, 41), (1_000_000, 10_000)):
                    u = comm.predict(cname, sname, L, k, dp, scalar)
                    t = comm.predict(cname, sname, L, k, dp, topo)
                    assert u.bytes_on_wire == t.bytes_on_wire
                    assert u.n_messages == t.n_messages
                    assert u.seconds == t.seconds  # bit-for-bit


def test_pattern_axes_sums_to_flat_pattern():
    from repro.comm.cost import _pattern, pattern_axes

    for coll in sorted(comm.COLLECTIVES):
        for dp in ((8,), (2, 4), (2, 4, 8)):
            per_axis = pattern_axes(coll, 4096, 512.0, dp)
            assert len(per_axis) == len(dp)
            by, msgs = _pattern(coll, 4096, 512.0, dp)
            assert sum(b for b, _ in per_axis) == by
            assert sum(m for _, m in per_axis) == msgs


def test_pattern_axes_hierarchical_splits_inter_intra():
    from repro.comm.cost import pattern_axes

    per_axis = pattern_axes("hierarchical", 1024, 128.0, (2, 4))
    # outer axis moves only the compressed payload; inner the dense psum
    assert per_axis[0] == (128.0, 1)
    assert per_axis[1] == (2.0 * 3 / 4 * 1024 * 4, 6)
    # flat collectives charge the (slowest) outermost axis of their span
    flat = pattern_axes("sparse_allgather", 1024, 128.0, (2, 4))
    assert flat[1] == (0.0, 0) and flat[0][1] == 7


def test_pattern_axes_skips_size1_axes():
    """A size-1 axis carries no traffic: flat stages must charge the
    outermost axis that actually has workers, so a degenerate (1, N) mesh
    prices exactly like the single-axis (N,) mesh under any topology."""
    from repro.comm.cost import pattern_axes

    flat = pattern_axes("sparse_allgather", 1024, 128.0, (1, 4))
    assert flat[0] == (0.0, 0) and flat[1] == (384.0, 3)
    hier = pattern_axes("hierarchical", 1024, 128.0, (1, 2, 4))
    assert hier[0] == (0.0, 0)  # inter payload crosses the size-2 axis
    assert hier[1] == (128.0, 1)
    topo = comm.LinkTopo(
        (comm.AlphaBeta(1e-5, 1e-9), comm.AlphaBeta(1e-6, 1e-11))
    )
    for coll in sorted(comm.COLLECTIVES):
        degenerate = comm.predict("coo_fp32", coll, 10**6, 10**5, (1, 8), topo)
        flat_mesh = comm.predict(
            "coo_fp32", coll, 10**6, 10**5, (8,),
            comm.LinkTopo((topo.links[1],)),
        )
        assert degenerate.seconds == flat_mesh.seconds
        assert degenerate.bytes_on_wire == flat_mesh.bytes_on_wire


def test_linktopo_rank_must_match_dp_axes():
    topo3 = comm.LinkTopo.uniform(comm.AlphaBeta(), 3)
    with pytest.raises(ValueError, match="3 per-axis links"):
        comm.predict("coo_fp32", "sparse_allgather", 64, 2, (2, 4), topo3)
    with pytest.raises(ValueError, match="per-axis links"):
        choose_leaf(64, 2, (8,), topo3)
    with pytest.raises(ValueError, match="at least one"):
        comm.LinkTopo(())


SLOW_OUTER = comm.LinkTopo(
    (comm.AlphaBeta(alpha=1e-5, beta=1e-10),
     comm.AlphaBeta(alpha=1e-6, beta=1e-11))  # outer beta = 10x intra
)


def test_slow_outer_topo_flips_choice_to_hierarchical():
    """The acceptance setting: a (2, 4) dp mesh whose outer-axis beta is
    >= 10x the intra-axis beta must plan `hierarchical` for large
    moderately-sparse leaves — which a uniform bandwidth-only model
    provably never strictly prefers (docs/comm.md envelope proof)."""
    L, k = 1_000_000, 100_000
    het = choose_leaf(L, k, (2, 4), SLOW_OUTER)
    assert het.collective == "hierarchical"
    # same leaf, uniform bandwidth-only link: sits on the envelope
    uni = choose_leaf(
        L, k, (2, 4), comm.AlphaBeta(alpha=0.0, beta=1e-11)
    )
    assert uni.collective != "hierarchical"
    # and the planner's pick is strictly cheaper than both flat patterns
    for coll in ("dense_allreduce", "sparse_allgather"):
        fixed = choose_leaf(L, k, (2, 4), SLOW_OUTER, collectives=[coll])
        assert het.cost.seconds < fixed.cost.seconds


def test_plan_tree_slow_outer_selects_hierarchical_for_large_leaves():
    tree = {
        "big": _leaf(1_000_000, 0.1),
        "bias": _leaf(64, 0.05),
    }
    cp = plan_tree(tree, (2, 4), SLOW_OUTER)
    assert cp.decisions["big"].collective == "hierarchical"
    assert cp.model == SLOW_OUTER  # CommPlan carries the topology
    uni = plan_tree(tree, (2, 4))
    assert isinstance(uni.model, comm.LinkTopo) and uni.model.is_uniform


def test_parse_link_topo_specs():
    topo = comm.parse_link_topo(
        "inter:1e-5,1e-10;intra:1e-6,1e-11", ("pod", "data")
    )
    assert topo.links == (
        comm.AlphaBeta(1e-5, 1e-10), comm.AlphaBeta(1e-6, 1e-11)
    )
    # axis names directly, any order in the spec; result is dp-axis order
    topo2 = comm.parse_link_topo(
        "data:1e-6,1e-11;pod:1e-5,1e-10", ("pod", "data")
    )
    assert topo2 == topo
    # bare alpha,beta is uniform
    uni = comm.parse_link_topo("2e-5,3e-11", ("pod", "data"))
    assert uni == comm.LinkTopo.uniform(comm.AlphaBeta(2e-5, 3e-11), 2)
    with pytest.raises(ValueError, match="unknown link class"):
        comm.parse_link_topo("bogus:1,1", ("data",))
    with pytest.raises(ValueError, match="no outer axes"):
        comm.parse_link_topo("inter:1,1;intra:1,1", ("data",))
    with pytest.raises(ValueError, match="not covered"):
        comm.parse_link_topo("intra:1,1", ("pod", "data"))
    with pytest.raises(ValueError, match="assigned twice"):
        comm.parse_link_topo("intra:1,1;data:2,2", ("pod", "data"))


def test_distconfig_link_topo_threads_into_build_plan():
    class _Mesh2:
        shape: ClassVar[dict] = {"pod": 2, "data": 4}

    shapes = _shapes({"big": 1_000_000, "bias": 64})
    specs = {"big": P(None), "bias": P(None)}
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.1),
        codec="auto", collective="auto",
        dp_axes=("pod", "data"), link_topo=SLOW_OUTER,
    )
    plan = build_plan(shapes, specs, _Mesh2(), 0.1, dist)
    assert plan["big"].collective == "hierarchical"
    assert dist.resolved_link_model() is SLOW_OUTER
    # without the topo the same mesh plans a flat collective for "big"
    uni = dataclasses.replace(dist, link_topo=None)
    plan_u = build_plan(shapes, specs, _Mesh2(), 0.1, uni)
    assert plan_u["big"].collective != "hierarchical"
    # comm_round_cost prices the round under the same topology
    from repro.core.distributed import comm_round_cost

    est = comm_round_cost(plan, dist, _Mesh2())
    est_u = comm_round_cost(plan_u, uni, _Mesh2())
    assert est.seconds < est_u.seconds


def test_simulator_dp_shape_and_link_topo():
    grad_fn = _toy()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.5, mu=1.0)
    sim = DistributedSim(
        grad_fn, 2, 2, cfg, learning_rate=0.9,
        codec="auto", collective="auto",
        dp_shape=(2, 1), link_topo=SLOW_OUTER,
    )
    assert sim.resolved_link_model is SLOW_OUTER
    est = sim.wire_bytes_per_round()
    assert est.bytes_on_wire >= 0 and est.seconds > 0
    # numerics stay dense-equivalent regardless of the notional grouping
    ref = DistributedSim(grad_fn, 2, 2, cfg, learning_rate=0.9)
    fin, _ = sim.run(jnp.array([0.0, 1.0]), 30)
    fin_ref, _ = ref.run(jnp.array([0.0, 1.0]), 30)
    np.testing.assert_allclose(
        np.asarray(fin.theta), np.asarray(fin_ref.theta), rtol=1e-5
    )
    with pytest.raises(ValueError, match="does not factor"):
        DistributedSim(grad_fn, 2, 2, cfg, dp_shape=(3,))


def test_calibrate_topo_single_device_falls_back():
    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("pod", "data"))
    res = comm.calibrate_topo(mesh=mesh, dp_axes=("pod", "data"))
    assert not res.calibrated
    assert res.topo == comm.LinkTopo.uniform(comm.AlphaBeta(), 2)
    assert res.axes == ("pod", "data")
    assert all(not c.calibrated for c in res.per_axis)


def test_time_collective_probe_runs_in_process():
    """The probe harness itself (shard_map ladder + median timing) on the
    in-process single-device mesh: a 1-worker dp group moves nothing, so
    the sample's ring pattern is (0 messages, 0 bytes), but the probe
    still executes and reports a positive wall time."""
    from repro.comm import calibrate as cal
    from repro.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    for coll in ("dense_allreduce", "sparse_allgather"):
        s = cal.time_collective(mesh, ("data",), 512, coll, iters=2)
        assert (s.collective, s.length) == (coll, 512)
        assert s.n_messages == 0 and s.bytes_on_wire == 0
        assert s.seconds > 0
    with pytest.raises(ValueError, match="not implemented"):
        cal.time_collective(mesh, ("data",), 512, "hierarchical")


def test_calibrate_rejects_dp_axes_without_mesh():
    """dp_axes name axes of a specific mesh; without it the entry points
    must refuse rather than silently probe a different topology."""
    with pytest.raises(ValueError, match="ambiguous"):
        comm.calibrate_topo(dp_axes=("pod", "data"))
    with pytest.raises(ValueError, match="ambiguous"):
        comm.run_calibration(dp_axes=("pod", "data"))


# ---------------------------------------------------------------------------
# calibration fit
# ---------------------------------------------------------------------------
def test_fit_alpha_beta_recovers_synthetic_model():
    true = comm.AlphaBeta(alpha=2e-5, beta=3e-10)
    rows = [(7, 1_000), (14, 100_000), (3, 5_000_000), (15, 40_000)]
    samples = [
        Sample("probe", i, m, b, m * true.alpha + b * true.beta)
        for i, (m, b) in enumerate(rows)
    ]
    fit = fit_alpha_beta(samples)
    assert fit.alpha == pytest.approx(true.alpha, rel=1e-6)
    assert fit.beta == pytest.approx(true.beta, rel=1e-6)


def test_fit_alpha_beta_clamps_degenerate_fits():
    # bytes explain everything -> alpha clamps to its floor, beta refit
    samples = [
        Sample("probe", i, 1, b, b * 1e-9) for i, b in enumerate(
            (10_000, 500_000, 2_000_000)
        )
    ]
    fit = fit_alpha_beta(samples)
    assert fit.alpha >= 0 and fit.beta == pytest.approx(1e-9, rel=1e-3)
    with pytest.raises(ValueError):
        fit_alpha_beta([])


def test_calibrate_single_device_falls_back():
    # the main pytest process sees one CPU device (dry-run isolation
    # contract) -> calibrate must not crash, must flag uncalibrated
    res = comm.run_calibration()
    if len(jax.devices()) < 2:
        assert not res.calibrated
        assert res.model == comm.AlphaBeta()
    else:  # pragma: no cover - multi-device env
        assert res.calibrated and len(res.samples) > 0
    # a caller-supplied mesh whose dp group has one worker has no wire to
    # probe either: must fall back, not fit the clamp floors as if real
    from repro.compat import make_mesh

    one = make_mesh((1,), ("data",))
    res1 = comm.run_calibration(mesh=one, dp_axes=("data",))
    assert not res1.calibrated
    assert res1.model == comm.AlphaBeta()


# ---------------------------------------------------------------------------
# fused fastpath planning (ISSUE 5: per-leaf fused flag via the
# measured-throughput table)
# ---------------------------------------------------------------------------
def test_choose_leaf_fastpath_off_never_fuses():
    d = choose_leaf(65_536, 64, (8,))
    assert d.fused is False
    # explicit off is identical to the default
    assert choose_leaf(65_536, 64, (8,), fastpath="off") == d


def test_choose_leaf_fastpath_on_fuses_fusable_wire_only():
    on = choose_leaf(
        65_536, 64, (8,),
        codecs=["coo_fp32"], collectives=["sparse_allgather"],
        fastpath="on",
    )
    assert on.fused
    # bitmap_dense has no fused encode epilogue (its wire format IS the
    # dense mask); dense_allreduce moves no payload — neither ever fuses
    bm = choose_leaf(
        65_536, 64, (8,),
        codecs=["bitmap_dense"], collectives=["sparse_allgather"],
        fastpath="on",
    )
    assert not bm.fused
    da = choose_leaf(
        65_536, 64, (8,),
        codecs=["coo_fp32"], collectives=["dense_allreduce"],
        fastpath="on",
    )
    assert not da.fused


def test_choose_leaf_fastpath_auto_prices_with_table():
    big = choose_leaf(
        65_536, 64, (8,),
        codecs=["coo_fp32"], collectives=["sparse_allgather"],
        fastpath="auto",
    )
    assert big.fused  # analytic default table: fused traffic is lower
    tiny = choose_leaf(
        100, 4, (8,),
        codecs=["coo_fp32"], collectives=["sparse_allgather"],
        fastpath="auto",
    )
    assert not tiny.fused  # one padded 8192-tile dwarfs a 100-elem leaf
    # a table measuring the fused path slower flips the big leaf too
    slow_fused = comm.ThroughputTable(fused_bps=1e6, unfused_bps=1e12)
    forced = choose_leaf(
        65_536, 64, (8,),
        codecs=["coo_fp32"], collectives=["sparse_allgather"],
        fastpath="auto", compute=slow_fused,
    )
    assert not forced.fused
    with pytest.raises(ValueError, match="fastpath"):
        choose_leaf(65_536, 64, (8,), fastpath="bogus")


def test_choose_leaf_shape_gate_dense_selection_stays_unfused():
    """k beyond the per-tile candidate budget (S ~> 1.5%) is not fusable."""
    from repro.comm import fastpath as fp

    L = 8192
    k = 1024  # S = 12.5%
    assert not fp.shape_fusable(L, k)[0]
    d = choose_leaf(
        L, k, (8,),
        codecs=["coo_fp32"], collectives=["sparse_allgather"],
        fastpath="on",
    )
    assert not d.fused


def test_build_plan_fills_fused_flags_per_leaf():
    """build_plan threads DistConfig.fastpath into per-leaf fused flags:
    big fusable leaves fuse, tiny leaves under 'auto' decline (padding
    overhead), and fastpath='off' leaves the field None."""

    class _Mesh:
        shape: ClassVar[dict] = {"data": 8}

    shapes = {
        "emb": jax.ShapeDtypeStruct((65_536,), jnp.float32),
        "bias": jax.ShapeDtypeStruct((100,), jnp.float32),
    }
    specs = {"emb": P(None), "bias": P(None)}
    base = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.001),
        codec="coo_fp32", collective="sparse_allgather",
        dp_axes=("data",),
    )
    plan_off = build_plan(shapes, specs, _Mesh(), 0.001, base)
    assert plan_off["emb"].fused is None and plan_off["bias"].fused is None
    on = dataclasses.replace(base, fastpath="on")
    plan_on = build_plan(shapes, specs, _Mesh(), 0.001, on)
    assert plan_on["emb"].fused is True
    assert plan_on["bias"].fused is True  # "on" forces every fusable leaf
    auto = dataclasses.replace(base, fastpath="auto")
    if jax.default_backend() == "tpu":  # pragma: no cover - TPU env
        plan_auto = build_plan(shapes, specs, _Mesh(), 0.001, auto)
        assert plan_auto["emb"].fused is True
        assert plan_auto["bias"].fused is False
    else:
        # off-TPU "auto" resolves to "off" (interpret mode never wins)
        plan_auto = build_plan(shapes, specs, _Mesh(), 0.001, auto)
        assert plan_auto["emb"].fused is None
    # a non-fusable sparsifier config zeroes the whole plan
    thr = dataclasses.replace(
        on,
        sparsifier=SparsifierConfig(
            kind="regtopk", sparsity=0.001, selector="threshold"
        ),
    )
    plan_thr = build_plan(shapes, specs, _Mesh(), 0.001, thr)
    assert plan_thr["emb"].fused is None


def test_plan_tree_threads_fastpath():
    tree = {
        "emb": LeafPlan((65_536,), (65_536,), 65_536, 64, P(None)),
        "bias": LeafPlan((100,), (100,), 100, 4, P(None)),
    }
    cp = plan_tree(
        tree, (8,), codecs=["coo_fp32"],
        collectives=["sparse_allgather"], fastpath="auto",
    )
    assert cp.decisions["emb"].fused is True
    assert cp.decisions["bias"].fused is False


def test_fusability_matrix_config_rules():
    from repro.comm import fastpath as fp

    ok = SparsifierConfig(kind="regtopk", sparsity=0.001, mu=1.0)
    assert fp.config_fusable(ok)[0]
    assert fp.config_fusable(
        SparsifierConfig(kind="topk", sparsity=0.001)
    )[0]
    for bad in (
        SparsifierConfig(kind="cyclic", sparsity=0.001),
        SparsifierConfig(kind="regtopk", sparsity=0.001,
                         selector="threshold"),
        SparsifierConfig(kind="regtopk", sparsity=0.001, y=0.0),
        # unsaturated regularizer: tanh((1+Q)/mu) < 1 diverges from the
        # unfused path's untouched unsent scores
        SparsifierConfig(kind="regtopk", sparsity=0.001, mu=1e9),
    ):
        assert not fp.config_fusable(bad)[0], bad


def test_throughput_table_measure_fits_positive_rates():
    """The measured-throughput refit actually times both paths and returns
    usable (positive, finite) effective rates."""
    t = comm.ThroughputTable.measure(
        length=8192, k=8, iters=1, interpret=True
    )
    assert 0 < t.fused_bps < float("inf")
    assert 0 < t.unfused_bps < float("inf")
    # rates feed straight into the auto pricing
    assert isinstance(t.prefers_fused(8192, 8), bool)


# ---------------------------------------------------------------------------
# __post_init__ auto-planning x participation (ISSUE 8 satellite)
# ---------------------------------------------------------------------------
def _capture_choose_leaf(monkeypatch):
    """Spy on autotune.choose_leaf, recording (k, participants) per call."""
    from repro.comm import autotune as at

    captured = []
    real = at.choose_leaf

    def spy(length, k, dp_sizes, link, **kw):
        captured.append((int(k), kw.get("participants")))
        return real(length, k, dp_sizes, link, **kw)

    monkeypatch.setattr(at, "choose_leaf", spy)
    return captured


@pytest.mark.parametrize(
    "part",
    [
        None,
        comm.Participation("full"),
        comm.Participation("bernoulli", drop_rate=0.0),
        comm.Participation("bernoulli", drop_rate=0.25, seed=1),
        comm.Participation("round_robin", n_stragglers=3),
        comm.Participation(
            "stale", n_stragglers=2, staleness=2, discount=0.5
        ),
    ],
    ids=["none", "full", "bern0", "bernoulli", "round_robin", "stale"],
)
def test_sim_auto_planning_threads_expected_participants(monkeypatch, part):
    """``DistributedSim.__post_init__`` auto-planning must price partial
    rounds: the schedule's ``expected_participants(N)`` travels into
    ``autotune.choose_leaf(participants=)`` verbatim, and the full /
    disabled / zero-drop schedules plan at ``participants=None`` (the
    dense-round cost, bit-identical to no participation at all)."""
    captured = _capture_choose_leaf(monkeypatch)
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25, mu=1.0)
    DistributedSim(
        lambda th, n: th, 8, 16, cfg,
        codec="auto", collective="auto", participation=part,
    )
    assert len(captured) == 1
    _, got = captured[0]
    if part is None or part.is_full:
        assert got is None
    else:
        assert got == part.expected_participants(8)


def test_sim_auto_planning_adaptive_prices_capacity(monkeypatch):
    """With an adaptive controller the planner prices the payload the
    round actually ships — capacity ``k_max`` — not the static-sparsity
    k, and participation still rides along."""
    captured = _capture_choose_leaf(monkeypatch)
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.05, mu=1.0)
    ctrl = comm.AdaptiveKController(budget=1.0, k_min=2, k_max=32)
    part = comm.Participation("round_robin", n_stragglers=2)
    DistributedSim(
        lambda th, n: th, 8, 64, cfg,
        codec="auto", collective="auto",
        participation=part, adaptive_k=ctrl,
    )
    assert captured == [(32, 6.0)]
