"""Partial-participation / straggler-tolerant collectives (ISSUE 4 tentpole).

Covers the `repro.comm.participation` layer end to end: full-participation
schedules are bit-for-bit identical to the historical all-workers path for
every collective; dropped-worker rounds conserve the renormalized weights;
bounded-staleness delivery applies each buffered payload exactly once; and
a subprocess shard_map run checks partial-round dense <-> payload
equivalence in the real runtime.
"""
import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import comm
from repro.core import DistributedSim, SparsifierConfig

COLLECTIVES = ["dense_allreduce", "sparse_allgather", "hierarchical"]


def _linreg_setup(n_workers=4, rows=8, dim=16, seed=0):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (n_workers, rows, dim))
    theta_star = jax.random.normal(jax.random.fold_in(key, 1), (dim,))
    y = jnp.einsum("nij,j->ni", A, theta_star)

    def grad_fn(theta, n):
        r = A[n] @ theta - y[n]
        return A[n].T @ r / rows

    return grad_fn, theta_star, dim


# ---------------------------------------------------------------------------
# schedule masks
# ---------------------------------------------------------------------------
def test_full_mask_is_all_ones():
    p = comm.Participation("full")
    assert p.is_full
    np.testing.assert_array_equal(np.asarray(p.round_mask(0, 6)), 1.0)
    np.testing.assert_array_equal(np.asarray(p.round_mask(17, 6)), 1.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(0, 100))
def test_round_robin_drops_exactly_n_stragglers(n_workers, round_idx):
    ns = max(1, n_workers // 3)
    p = comm.Participation("round_robin", n_stragglers=ns)
    m = np.asarray(p.round_mask(round_idx, n_workers))
    assert set(np.unique(m)) <= {0.0, 1.0}
    assert int((1 - m).sum()) == ns


def test_round_robin_rotates_over_every_worker():
    n = 6
    p = comm.Participation("round_robin", n_stragglers=1)
    dropped = set()
    for r in range(n):
        m = np.asarray(p.round_mask(r, n))
        dropped.update(np.nonzero(m == 0)[0].tolist())
    assert dropped == set(range(n))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 200))
def test_bernoulli_always_keeps_at_least_one(round_idx):
    p = comm.Participation("bernoulli", drop_rate=0.95, seed=3)
    m = np.asarray(p.round_mask(round_idx, 8))
    assert m.sum() >= 1
    assert m[round_idx % 8] == 1.0  # the rotating liveness worker


def test_bernoulli_is_deterministic_common_knowledge():
    p = comm.Participation("bernoulli", drop_rate=0.5, seed=7)
    m1 = np.asarray(p.round_mask(13, 8))
    m2 = np.asarray(p.round_mask(13, 8))
    np.testing.assert_array_equal(m1, m2)
    # jit/scan-friendly with a traced round index
    m3 = np.asarray(jax.jit(lambda r: p.round_mask(r, 8))(13))
    np.testing.assert_array_equal(m1, m3)


def test_participation_validation():
    with pytest.raises(ValueError, match="unknown participation kind"):
        comm.Participation("bogus")
    with pytest.raises(ValueError, match="drop_rate"):
        comm.Participation("bernoulli", drop_rate=1.0)
    with pytest.raises(ValueError, match="n_stragglers"):
        comm.Participation("round_robin", n_stragglers=0)
    with pytest.raises(ValueError, match="every one"):
        comm.Participation("round_robin", n_stragglers=4).validate(4)
    comm.Participation("round_robin", n_stragglers=3).validate(4)
    # any non-full schedule needs a real (>1 worker) dp group
    with pytest.raises(ValueError, match="at least 2 workers"):
        comm.Participation("bernoulli", drop_rate=0.5).validate(1)
    comm.Participation("full").validate(1)


def test_parse_participation_specs():
    assert comm.parse_participation(None).is_full
    assert comm.parse_participation("full").is_full
    p = comm.parse_participation("bernoulli:0.25,11")
    assert (p.kind, p.drop_rate, p.seed) == ("bernoulli", 0.25, 11)
    p = comm.parse_participation("round_robin:2")
    assert (p.kind, p.n_stragglers) == ("round_robin", 2)
    p = comm.parse_participation("stale:1,3,0.5")
    assert (p.kind, p.staleness, p.discount) == ("stale", 3, 0.5)
    for bad in ("nope", "bernoulli", "round_robin:1,2", "full:1",
                "stale:1,2,3,4"):
        with pytest.raises(ValueError, match="participation"):
            comm.parse_participation(bad)


# ---------------------------------------------------------------------------
# weight renormalization conserves mass
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(0, 50), st.floats(0.1, 0.9))
def test_dropped_rounds_conserve_renormalized_weights(n, round_idx, rate):
    """The participating_weights hook: zero on dropped workers, sums to one
    over the participants — for every schedule and round."""
    base = jnp.full((n,), 1.0 / n)
    for p in (
        comm.Participation("full"),
        comm.Participation("bernoulli", drop_rate=rate),
        comm.Participation("round_robin", n_stragglers=max(1, n // 2 - 1)),
    ):
        w = np.asarray(p.participating_weights(base, round_idx))
        m = np.asarray(p.round_mask(round_idx, n))
        assert w.sum() == pytest.approx(1.0, rel=1e-6)
        np.testing.assert_array_equal(w[m == 0], 0.0)
        if m.sum() > 0:
            live = w[m > 0]
            np.testing.assert_allclose(live, live[0], rtol=1e-6)


def test_renormalize_weights_nonuniform():
    w = jnp.array([0.1, 0.2, 0.3, 0.4])
    m = jnp.array([1.0, 0.0, 1.0, 0.0])
    out = np.asarray(comm.renormalize_weights(w, m))
    np.testing.assert_allclose(out, [0.25, 0.0, 0.75, 0.0], rtol=1e-6)


# ---------------------------------------------------------------------------
# full participation is bit-for-bit the historical path (acceptance)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("collective", COLLECTIVES)
def test_full_participation_bitforbit(collective):
    grad_fn, theta_star, dim = _linreg_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25, mu=1.0)
    base = DistributedSim(
        grad_fn, 4, dim, cfg, learning_rate=0.05, collective=collective
    )
    full = DistributedSim(
        grad_fn, 4, dim, cfg, learning_rate=0.05, collective=collective,
        participation=comm.Participation("full"),
    )
    fb, tb = base.run(jnp.zeros(dim), 40)
    ff, tf = full.run(jnp.zeros(dim), 40)
    np.testing.assert_array_equal(np.asarray(tb), np.asarray(tf))
    np.testing.assert_array_equal(
        np.asarray(fb.theta), np.asarray(ff.theta)
    )


def test_zero_rate_bernoulli_is_full():
    assert comm.Participation("bernoulli", drop_rate=0.0).is_full


# ---------------------------------------------------------------------------
# dropped workers: error feedback covers non-participation
# ---------------------------------------------------------------------------
def test_dropped_worker_keeps_accumulated_gradient():
    """One partial round: the straggler's whole accumulated gradient stays
    in eps (nothing reached the server), its posterior stats stay frozen,
    and the broadcast is the renormalized mean of the participants."""
    grad_fn, _, dim = _linreg_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25, mu=1.0)
    part = comm.Participation("round_robin", n_stragglers=1)
    sim = DistributedSim(
        grad_fn, 4, dim, cfg, learning_rate=0.05, participation=part
    )
    state = sim.init(jnp.zeros(dim))
    grads = jax.vmap(grad_fn, in_axes=(None, 0))(
        state.theta, jnp.arange(4)
    )
    new_state, g_agg = sim.step_fn(state)
    m = np.asarray(part.round_mask(0, 4))
    (dropped,) = np.nonzero(m == 0)[0]
    # eps_dropped == full accumulated gradient (eps0 = 0, so == its grad)
    np.testing.assert_allclose(
        np.asarray(new_state.worker_states.eps[dropped]),
        np.asarray(grads[dropped]),
        rtol=1e-6,
    )
    # posterior stats frozen at the (never-sent) initial state
    np.testing.assert_array_equal(
        np.asarray(new_state.worker_states.s_prev[dropped]), 0.0
    )
    # broadcast = renormalized mean of the participants' sparsified grads
    live = np.nonzero(m > 0)[0]
    k = 4  # 0.25 * 16
    expect = np.zeros(dim, np.float32)
    for n in live:
        g = np.asarray(grads[n])
        idx = np.argsort(-np.abs(g))[:k]
        expect[idx] += g[idx] / len(live)
    np.testing.assert_allclose(np.asarray(g_agg), expect, rtol=1e-5)
    # participants' error feedback is the usual a - ghat
    for n in live:
        g = np.asarray(grads[n])
        idx = np.argsort(-np.abs(g))[:k]
        eps_exp = g.copy()
        eps_exp[idx] = 0.0
        np.testing.assert_allclose(
            np.asarray(new_state.worker_states.eps[n]), eps_exp, rtol=1e-5
        )


@pytest.mark.parametrize("schedule", ["round_robin", "bernoulli", "stale"])
def test_partial_payload_collectives_match_dense(schedule):
    """Under every schedule, sparse_allgather / hierarchical must track
    dense_allreduce exactly — participation composes with the collective,
    it is not baked into one."""
    grad_fn, theta_star, dim = _linreg_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25, mu=1.0)
    part = {
        "round_robin": comm.Participation("round_robin", n_stragglers=1),
        "bernoulli": comm.Participation("bernoulli", drop_rate=0.4),
        "stale": comm.Participation(
            "stale", n_stragglers=1, staleness=2, discount=0.5
        ),
    }[schedule]
    out = {}
    for coll in COLLECTIVES:
        sim = DistributedSim(
            grad_fn, 4, dim, cfg, learning_rate=0.05, collective=coll,
            participation=part,
        )
        fin, _ = sim.run(jnp.zeros(dim), 60)
        out[coll] = np.asarray(fin.theta)
    for coll in COLLECTIVES[1:]:
        np.testing.assert_allclose(
            out[coll], out["dense_allreduce"], rtol=1e-5, atol=1e-6
        )


# ---------------------------------------------------------------------------
# bounded staleness: each payload delivered exactly once
# ---------------------------------------------------------------------------
def _run_stale_against_mirror(staleness, discount, steps, n_stragglers=1):
    """Drive the sim with constant one-hot gradients and compare every
    broadcast against an independent python delivery model that, by
    construction, applies each buffered payload exactly once (at its
    deadline, or early if its worker straggles again first)."""
    N = 4
    eye = jnp.eye(N)

    def grad_fn(theta, n):
        return eye[n]

    part = comm.Participation(
        "stale", n_stragglers=n_stragglers, staleness=staleness,
        discount=discount,
    )
    cfg = SparsifierConfig(kind="none")
    sim = DistributedSim(
        grad_fn, N, N, cfg, learning_rate=0.0, participation=part
    )
    state = sim.init(jnp.zeros(N))
    got = []
    for _ in range(steps):
        state, g = sim.step_fn(state)
        got.append(np.asarray(g))

    pending = {}  # worker -> (contribution vector, delivery deadline)
    deliveries = {}  # (worker, stored_round) -> count
    expect = []
    for t in range(steps):
        m = np.asarray(part.round_mask(t, N))
        live = np.nonzero(m > 0)[0]
        agg = np.zeros(N)
        for n in live:
            agg[n] += 1.0 / len(live)
        dropped = np.nonzero(m == 0)[0]
        for n in list(pending):
            contrib, deadline, stored = pending[n]
            if t >= deadline or n in dropped:
                agg += contrib
                deliveries[(n, stored)] = deliveries.get((n, stored), 0) + 1
                del pending[n]
        for n in dropped:
            pending[n] = (discount * (1.0 / N) * np.eye(N)[n], t + staleness, t)
        expect.append(agg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=1e-6)
    assert all(c == 1 for c in deliveries.values())
    return deliveries


def test_stale_delivery_applies_each_payload_exactly_once():
    # staleness shorter than the straggler rotation: clean late deliveries
    d = _run_stale_against_mirror(staleness=2, discount=0.5, steps=16)
    assert len(d) > 0


def test_stale_delivery_early_flush_on_re_drop():
    # staleness longer than the rotation period: the worker straggles again
    # while its payload is still buffered -> the old payload must land
    # early (exactly once), not be overwritten.
    d = _run_stale_against_mirror(staleness=6, discount=1.0, steps=20)
    assert len(d) > 0


def test_stale_pending_state_shape_and_inactive_default():
    grad_fn, _, dim = _linreg_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25)
    stale = DistributedSim(
        grad_fn, 4, dim, cfg,
        participation=comm.Participation("stale", n_stragglers=1),
    )
    st_ = stale.init(jnp.zeros(dim))
    assert st_.pending.shape == (4, dim)
    assert st_.pending_age.shape == (4,)
    plain = DistributedSim(grad_fn, 4, dim, cfg)
    assert plain.init(jnp.zeros(dim)).pending is None


def test_g_agg_prev_is_what_the_server_broadcast():
    """RegTop-k's posterior must condition on the *actual* broadcast —
    including late deliveries — not the full-participation ideal."""
    grad_fn, _, dim = _linreg_setup()
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.25, mu=1.0)
    sim = DistributedSim(
        grad_fn, 4, dim, cfg, learning_rate=0.05,
        participation=comm.Participation(
            "stale", n_stragglers=1, staleness=1, discount=0.5
        ),
    )
    state = sim.init(jnp.zeros(dim))
    for _ in range(3):
        state, g = sim.step_fn(state)
        np.testing.assert_array_equal(
            np.asarray(state.g_agg_prev), np.asarray(g)
        )


# ---------------------------------------------------------------------------
# partial-round cost accounting (acceptance: strictly below full)
# ---------------------------------------------------------------------------
def test_partial_round_cost_strictly_below_full():
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        comm_round_cost,
    )

    class _Mesh:
        shape: ClassVar[dict] = {"data": 8}

    plan = LeafPlan((4096,), (4096,), 4096, 64, P(None))
    base = DistConfig(codec="coo_fp32", collective="sparse_allgather")
    partial = dataclasses.replace(
        base,
        participation=comm.Participation("round_robin", n_stragglers=2),
    )
    full_cost = comm_round_cost(plan, base, _Mesh())
    part_cost = comm_round_cost(plan, partial, _Mesh())
    assert part_cost.bytes_on_wire < full_cost.bytes_on_wire
    assert part_cost.n_messages < full_cost.n_messages
    assert part_cost.seconds < full_cost.seconds
    # a full schedule prices identically to no schedule at all
    full_sched = dataclasses.replace(
        base, participation=comm.Participation("full")
    )
    assert comm_round_cost(plan, full_sched, _Mesh()) == full_cost


def test_pattern_axes_full_participants_reproduces_flat_pattern():
    for coll in COLLECTIVES:
        for dp in ((8,), (2, 4), (1, 4)):
            n = int(np.prod(dp))
            assert comm.pattern_axes(
                coll, 4096, 512.0, dp, participants=float(n)
            ) == comm.pattern_axes(coll, 4096, 512.0, dp)


def test_pattern_axes_partial_monotone_in_participants():
    by = [
        comm.pattern_axes(
            "sparse_allgather", 4096, 512.0, (8,), participants=p
        )[0][0]
        for p in (2.0, 4.0, 6.0, 8.0)
    ]
    assert by == sorted(by)
    assert by[0] < by[-1]


def test_simulator_wire_bytes_account_for_participation():
    grad_fn, _, dim = _linreg_setup(dim=4096)
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.01)
    full = DistributedSim(
        grad_fn, 4, 4096, cfg, collective="sparse_allgather"
    )
    part = DistributedSim(
        grad_fn, 4, 4096, cfg, collective="sparse_allgather",
        participation=comm.Participation("round_robin", n_stragglers=1),
    )
    assert (
        part.wire_bytes_per_round().bytes_on_wire
        < full.wire_bytes_per_round().bytes_on_wire
    )


def test_autotune_accepts_participants():
    d_full = comm.choose_leaf(10**6, 10**4, (8,))
    d_part = comm.choose_leaf(10**6, 10**4, (8,), participants=5.0)
    assert d_part.cost.seconds < d_full.cost.seconds


# ---------------------------------------------------------------------------
# distributed runtime guards
# ---------------------------------------------------------------------------
def test_runtime_rejects_stale_participation():
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        make_sparsify_aggregate,
    )

    class _Mesh:
        shape: ClassVar[dict] = {"data": 4}

    plan = {"w": LeafPlan((64,), (64,), 64, 4, P(None))}
    dist = DistConfig(
        participation=comm.Participation("stale", n_stragglers=1)
    )
    with pytest.raises(ValueError, match="simulator-only"):
        make_sparsify_aggregate(_Mesh(), plan, None, None, dist, 4)


def test_runtime_rejects_overfull_straggler_count():
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import (
        DistConfig,
        LeafPlan,
        make_sparsify_aggregate,
    )

    class _Mesh:
        shape: ClassVar[dict] = {"data": 4}

    plan = {"w": LeafPlan((64,), (64,), 64, 4, P(None))}
    dist = DistConfig(
        participation=comm.Participation("round_robin", n_stragglers=4)
    )
    with pytest.raises(ValueError, match="every one"):
        make_sparsify_aggregate(_Mesh(), plan, None, None, dist, 4)


# ---------------------------------------------------------------------------
# shard_map runtime equivalence for partial rounds (subprocess)
# ---------------------------------------------------------------------------
SUB_CODE = """
import json
import jax, jax.numpy as jnp
from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
from repro.models import ModelConfig, get_family
from repro.core.distributed import (DistConfig, assemble,
                                    init_sparsifier_state)
from repro.core.sparsify import SparsifierConfig
from repro.optim import OptConfig, make_optimizer
from repro.data import TokenPipeline
from repro import comm

cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab=256, remat=False)
mod = get_family(cfg)

def train(collective, participation, steps=6):
    dist = DistConfig(
        sparsifier=SparsifierConfig(kind="regtopk", sparsity=0.05, mu=1.0),
        optimizer=OptConfig(kind="adam", learning_rate=3e-3),
        codec="coo_fp32", collective=collective, microbatches=1,
        dp_axes=("data",), participation=participation)
    asm = assemble(mod, cfg, dist, mesh)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(dist.optimizer)
    opt_state = opt.init(params)
    sp_state, _ = init_sparsifier_state(asm.plan, 4, mesh, ("data",),
                                        jnp.float32)
    pipe = TokenPipeline(cfg, global_batch=8, seq=32)
    step = jax.jit(asm.train_step)
    losses = []
    with mesh:
        for t in range(steps):
            params, opt_state, sp_state, m = step(
                params, opt_state, sp_state, pipe.batch_at(t))
            losses.append(float(m["loss"]))
    return losses

base = train("dense_allreduce", None)
full = train("dense_allreduce", comm.Participation("full"))
rr = comm.Participation("round_robin", n_stragglers=1)
rr_dense = train("dense_allreduce", rr)
rr_sparse = train("sparse_allgather", rr)
print(json.dumps({
    "full_bitforbit": base == full,
    "rr_diff": max(abs(a - b) for a, b in zip(rr_dense, rr_sparse)),
    "rr_vs_base": max(abs(a - b) for a, b in zip(rr_dense, base)),
    "rr_finite": all(x == x for x in rr_dense),
}))
"""


def test_shard_map_partial_participation_round():
    """The real shard_map runtime: Participation('full') is bit-for-bit
    the no-participation path, and a partial (round-robin) round gives the
    same numerics under dense_allreduce and sparse_allgather — the
    dense <-> payload equivalence of tests/test_comm.py, held under
    partial participation."""
    from tests.test_distributed import run_sub

    res = run_sub(SUB_CODE)
    assert res["full_bitforbit"] is True
    assert res["rr_finite"]
    assert res["rr_diff"] < 1e-4
    # the partial run actually differs from the full run (workers dropped)
    assert res["rr_vs_base"] > 0


# ---------------------------------------------------------------------------
# fleet-scale S-of-N client sampling (ISSUE 9)
# ---------------------------------------------------------------------------
def test_sampled_fleet_scale_round():
    """N = 2000 clients, S = 32 sampled per round: the jitted sampled
    round gathers only the drawn clients' states, so idle clients are
    untouched (their round counter never advances) and per-round work is
    O(S·J), not O(N·J)."""
    N, S, J = 2000, 32, 64
    b = jax.random.normal(jax.random.PRNGKey(0), (N, J))
    part = comm.Participation("sampled", n_sampled=S, seed=5)
    sim = DistributedSim(
        lambda th, n: th - b[n], N, J,
        SparsifierConfig(kind="regtopk", sparsity=0.1, mu=1.0),
        learning_rate=1e-2, collective="sparse_allgather",
        participation=part, weighting="coordinate",
    )
    step = jax.jit(lambda s: sim.step_fn(s)[0])
    s1 = step(sim.init(jnp.zeros(J)))
    s2 = step(s1)
    widx0 = np.asarray(part.round_participants(0, N))
    t1 = np.asarray(s1.worker_states.t)
    assert (t1[widx0] == 1).all()
    assert t1.sum() == S  # every unsampled client stayed idle
    assert np.asarray(s2.worker_states.t).sum() == 2 * S
    assert np.isfinite(np.asarray(s2.theta)).all()
    assert np.isfinite(np.asarray(s2.g_agg_prev)).all()
    # the round's aggregate only carries sampled clients' coordinates
    den = np.asarray(s2.w_agg_prev)
    assert ((den >= 0) & (den <= 1.0 + 1e-6)).all() and (den > 0).any()


def test_sampled_matches_explicit_subset_average():
    """One sampled round == hand-averaging the drawn clients' local
    sparsified gradients at weight 1/S (worker weighting)."""
    N, S, J = 12, 3, 24
    b = jax.random.normal(jax.random.PRNGKey(1), (N, J))
    part = comm.Participation("sampled", n_sampled=S, seed=9)
    cfg = SparsifierConfig(kind="topk", sparsity=0.25)
    sim = DistributedSim(
        lambda th, n: th - b[n], N, J, cfg,
        collective="sparse_allgather", participation=part,
    )
    state = sim.init(jnp.zeros(J))
    _, g_agg = jax.jit(sim.step_fn)(state)
    from repro.core.sparsify import make_sparsifier

    sp = make_sparsifier(cfg)
    widx = np.asarray(part.round_participants(0, N))
    want = np.zeros(J)
    for n in widx:
        ghat, _, _ = sp.step(
            sp.init(J), jnp.zeros(J) - b[n], jnp.zeros(J)
        )
        want = want + np.asarray(ghat) / S
    np.testing.assert_allclose(np.asarray(g_agg), want, rtol=1e-5,
                               atol=1e-6)
