"""Batched-decode serving example (smoke-size model on CPU).

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m

Runs the same serve_step the decode_32k / long_500k dry-run shapes lower.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "qwen2.5-3b"]
    if "--smoke" not in sys.argv:
        sys.argv += ["--smoke"]
    main()
