"""Paper Sec. 5.1: distributed linear regression with sparsified GD.

Tracks the optimality gap ||theta_t - theta*|| against the analytic
least-squares optimum for Top-k, RegTop-k, the coordinated variants
(ours), and dense GD, at a chosen sparsity.

Run: PYTHONPATH=src python examples/linreg_paper.py --sparsity 0.6
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sparsity", type=float, default=0.6)
    ap.add_argument("--steps", type=int, default=2500)
    ap.add_argument("--mu", type=float, default=16.0)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    N, J = 20, 100
    data = make_linreg(args.seed, N, J, 500)
    grad_fn = linreg_grad_fn(data)
    print(f"N={N} workers, J={J}, S={args.sparsity}; analytic optimum known")
    print(f"{'iter':>6s}", end="")
    kinds = ("topk", "regtopk", "coordtopk", "none")
    for k in kinds:
        print(f" {k:>12s}", end="")
    print()
    traces = {}
    for kind in kinds:
        cfg = SparsifierConfig(kind=kind, sparsity=args.sparsity, mu=args.mu)
        sim = DistributedSim(grad_fn, N, J, cfg, learning_rate=1e-2)
        _, tr = sim.run(
            jnp.zeros(J), args.steps,
            trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
        )
        traces[kind] = np.asarray(tr)
    for t in (0, 99, 499, 999, args.steps - 1):
        print(f"{t:6d}", end="")
        for k in kinds:
            print(f" {traces[k][t]:12.3e}", end="")
        print()


if __name__ == "__main__":
    main()
