"""End-to-end driver: sparsified data-parallel LM training.

Thin wrapper over repro.launch.train; by default trains the paper-proxy
model for a few hundred steps on the host mesh. For multi-worker CPU
simulation, run with extra host devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/distributed_train.py --steps 200

On a real TPU slice pass --mesh production --arch qwen2.5-3b (the ~100M+
configuration path exercised by the multi-pod dry-run).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += [
            "--arch", "paper-resnet-proxy", "--steps", "200",
            "--global-batch", "8", "--seq", "64", "--sparsity", "0.01",
            "--log-every", "20",
        ]
    main()
