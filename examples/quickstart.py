"""Quickstart: the paper's algorithm in 40 lines.

Reproduces the motivating example (paper Fig. 1): two workers whose large
gradient entries cancel at the server. Top-1 sparsification stalls;
RegTop-1 (the paper's Bayesian-regularized selection) tracks unsparsified
training.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import DistributedSim, SparsifierConfig

X = jnp.array([[100.0, 1.0], [-100.0, 1.0]])  # one data point per worker


def grad_fn(theta, n):
    e = jnp.exp(-jnp.dot(theta, X[n]))
    return -e * X[n] / (1 + e)


def loss(theta):
    return jnp.mean(jnp.log(1 + jnp.exp(-X @ theta)))


if __name__ == "__main__":
    print(f"{'iter':>5s} {'top-1':>10s} {'regtop-1':>10s} {'dense':>10s}")
    traces = {}
    for kind in ("topk", "regtopk", "none"):
        cfg = SparsifierConfig(kind=kind, sparsity=0.5, mu=1.0)
        sim = DistributedSim(grad_fn, n_workers=2, length=2,
                             sparsifier_cfg=cfg, learning_rate=0.9)
        _, tr = sim.run(jnp.array([0.0, 1.0]), 100, trace_fn=loss)
        traces[kind] = np.asarray(tr)
    for t in (0, 10, 25, 50, 75, 99):
        print(f"{t:5d} {traces['topk'][t]:10.4f} "
              f"{traces['regtopk'][t]:10.4f} {traces['none'][t]:10.4f}")
    print("\nTop-1 is pinned at its initial loss while RegTop-1 matches "
          "dense training — the paper's Fig. 1.")
