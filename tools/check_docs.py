#!/usr/bin/env python
"""Docs link checker (CI `docs` job; no third-party deps).

Validates, over the given markdown files (default: docs/*.md README.md):

* every markdown link ``[text](target)``: external http(s)/mailto links
  are skipped; ``#anchor`` targets must match a heading slug (GitHub
  slugging) in the target file; relative paths resolve from the linking
  file's directory;
* every backtick code span that looks like a repo file path
  (contains ``/`` and a known source suffix) must exist relative to the
  repo root; a ``path::symbol`` span additionally requires ``def symbol``
  / ``class symbol`` to be present in that file;
* every dotted ``repro.*`` path inside a backtick span must resolve via
  importlib against the live package (longest importable module prefix,
  then attribute walk), so the comm/paper_map docs cannot silently drift
  from the API surface.

Exit status 0 when everything resolves, 1 otherwise (one line per
problem). Used by tests/test_docs.py and .github/workflows/ci.yml.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
PATH_SUFFIXES = (".py", ".md", ".yml", ".yaml", ".txt", ".toml", ".cfg")
DOTTED_RE = re.compile(r"\brepro(?:\.\w+)+")

_resolve_cache: dict = {}


def strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks — their contents are illustrative."""
    out, in_fence = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        out.append("" if in_fence else line)
    return "\n".join(out)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: inline code/markup dropped, lowercase,
    punctuation removed, spaces to hyphens."""
    h = re.sub(r"[`*_]", "", heading).strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def heading_slugs(md_path: str) -> set:
    slugs = set()
    with open(md_path, encoding="utf-8") as f:
        text = strip_code_blocks(f.read())
    counts: dict = {}
    for line in text.splitlines():
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
        slugs.add(slug)
    return slugs


def check_link(md_path: str, target: str):
    """Yield error strings for one markdown link target."""
    if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:
        return
    path_part, _, anchor = target.partition("#")
    if path_part:
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(md_path), path_part)
        )
        if not os.path.exists(resolved):
            yield f"{md_path}: broken link path {target!r}"
            return
    else:
        resolved = md_path
    if anchor:
        if not resolved.endswith(".md"):
            return
        if anchor not in heading_slugs(resolved):
            yield f"{md_path}: broken anchor {target!r} (no such heading)"


def check_code_span(md_path: str, span: str):
    """Yield error strings for one backtick span that names a repo path."""
    path, _, symbol = span.partition("::")
    if "/" not in path or not path.endswith(PATH_SUFFIXES):
        return
    if not re.match(r"^[\w\-./]+$", path) or path.startswith(("/", "~")):
        return  # not a repo-relative path (absolute, URL-ish, or prose)
    resolved = os.path.join(REPO_ROOT, path)
    if not os.path.exists(resolved):
        yield f"{md_path}: referenced file {path!r} does not exist"
        return
    if symbol:
        with open(resolved, encoding="utf-8") as f:
            src = f.read()
        if not re.search(
            rf"^\s*(def|class)\s+{re.escape(symbol)}\b", src, re.M
        ):
            yield f"{md_path}: {path!r} has no def/class {symbol!r}"


def resolve_dotted(dotted: str) -> bool:
    """True iff a dotted ``repro.*`` path names an importable module or
    an attribute reachable from one (longest module prefix wins)."""
    if dotted in _resolve_cache:
        return _resolve_cache[dotted]
    import importlib

    src = os.path.join(REPO_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    parts = dotted.split(".")
    obj = None
    split = 0
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            split = i
            break
        except ImportError:
            continue
    ok = obj is not None
    if ok:
        for attr in parts[split:]:
            if not hasattr(obj, attr):
                ok = False
                break
            obj = getattr(obj, attr)
    _resolve_cache[dotted] = ok
    return ok


def check_dotted_spans(md_path: str, span: str):
    """Yield error strings for dotted ``repro.*`` references in a span.
    Call syntax is tolerated (``repro.comm.predict(...)`` checks
    ``repro.comm.predict``); file paths are the path checker's job."""
    if "/" in span:
        return
    for m in DOTTED_RE.finditer(span):
        dotted = m.group(0)
        if not resolve_dotted(dotted):
            yield (
                f"{md_path}: dotted reference {dotted!r} does not resolve "
                "via importlib (API drift?)"
            )


def check_file(md_path: str):
    with open(md_path, encoding="utf-8") as f:
        text = strip_code_blocks(f.read())
    errors = []
    for m in LINK_RE.finditer(text):
        errors.extend(check_link(md_path, m.group(1)))
    for m in CODE_SPAN_RE.finditer(text):
        errors.extend(check_code_span(md_path, m.group(1)))
        errors.extend(check_dotted_spans(md_path, m.group(1)))
    return errors


def main(argv):
    files = argv or [
        *sorted(glob.glob(os.path.join(REPO_ROOT, "docs", "*.md"))),
        os.path.join(REPO_ROOT, "README.md"),
    ]
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    checked = ", ".join(os.path.relpath(f, REPO_ROOT) for f in files)
    print(f"check_docs: {len(files)} files ({checked}): "
          f"{'OK' if not errors else f'{len(errors)} problem(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
