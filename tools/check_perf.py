#!/usr/bin/env python
"""CI perf-regression gate over the ``BENCH_*.json`` artifacts.

Compares a freshly produced bench JSON (``benchmarks/*_bench.py --json``)
against the committed baseline in `benchmarks/baselines/` and fails when
any row's interpret-mode ``us_per_call`` regresses beyond ``--max-ratio``
(default 1.5x, the ISSUE 5 gate).

Raw microseconds are not comparable across machines, so both sides are
first normalized by their run's ``meta.calib_us`` — a fixed XLA reference
computation timed in the same process (``benchmarks/common.py``) — and
the gate compares *relative* slowdowns:

    ratio = (cur.us / cur.calib_us) / (base.us / base.calib_us)

Two noise guards keep the 1.5x threshold meaningful on CPU runners:

* rows whose baseline time is ~0 (pure accounting rows) are skipped, and
* a regression must also exceed ``--slack-us`` (default 15 ms,
  *baseline-machine* microseconds: the current timing is converted into
  baseline units via the calibration ratio before the subtraction, so a
  faster runner doesn't shrink real regressions under the floor). CPU
  jit rows in the single-digit-ms range jitter several-x run-to-run
  even on an idle machine, so below the floor the gate only checks
  presence and sanity; its teeth are the interpret-mode kernel rows
  (tens to hundreds of ms), where a real 1.5x moves far more than the
  floor.

Rows present only in the current run are informational (new kernels have
no baseline yet — refresh with `tools/update_baselines.py`) unless
``--fail-on-new`` is given, which turns every such line into a failure —
`tools/update_baselines.py` uses it to self-check that the baseline it
just wrote covers every row the bench emits. Rows that *disappeared*
from the current run always fail, so a silently dropped benchmark cannot
masquerade as a perf win.

Usage: python tools/check_perf.py CURRENT.json BASELINE.json
       [--max-ratio R] [--slack-us US] [--fail-on-new]
"""
from __future__ import annotations

import argparse
import json
import sys

MIN_BASELINE_US = 1.0  # below this a row is accounting, not timing


def load(path: str):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    calib = float(doc["meta"]["calib_us"])
    if calib <= 0:
        raise SystemExit(f"{path}: non-positive calib_us {calib}")
    rows = {r["name"]: float(r["us_per_call"]) for r in doc["rows"]}
    return rows, calib, doc["meta"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail when normalized us_per_call exceeds "
                         "baseline by this factor (default 1.5)")
    ap.add_argument("--slack-us", type=float, default=15000.0,
                    help="absolute regression floor in baseline-machine "
                         "microseconds: rows slower by less than this "
                         "(after calibration conversion) never fail — "
                         "sub-5ms CPU rows jitter past any ratio")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="treat rows with no baseline entry as failures "
                         "instead of informational (the baseline self-"
                         "check in tools/update_baselines.py)")
    args = ap.parse_args(argv)

    cur, cur_calib, cur_meta = load(args.current)
    base, base_calib, base_meta = load(args.baseline)
    print(
        f"check_perf: {args.current} (calib {cur_calib:.0f}us, "
        f"jax {cur_meta.get('jax')}) vs {args.baseline} "
        f"(calib {base_calib:.0f}us, jax {base_meta.get('jax')})"
    )

    failures = []
    for name, base_us in sorted(base.items()):
        if name not in cur:
            failures.append(f"{name}: present in baseline, missing from run")
            continue
        if base_us < MIN_BASELINE_US:
            continue
        ratio = (cur[name] / cur_calib) / (base_us / base_calib)
        # current timing expressed in baseline-machine microseconds, so
        # the slack floor means the same thing on any runner speed
        cur_in_base = cur[name] * base_calib / cur_calib
        slow = ratio > args.max_ratio and (
            cur_in_base - base_us > args.slack_us
        )
        status = "FAIL" if slow else "ok"
        print(
            f"  {status:4s} {name}: {cur[name]:.0f}us vs {base_us:.0f}us "
            f"baseline (normalized ratio {ratio:.2f}x)"
        )
        if slow:
            failures.append(
                f"{name}: normalized {ratio:.2f}x > {args.max_ratio}x "
                f"(+{cur_in_base - base_us:.0f}us normalized)"
            )
    for name in sorted(set(cur) - set(base)):
        print(f"  new  {name}: {cur[name]:.0f}us (no baseline — refresh "
              "with tools/update_baselines.py)")
        if args.fail_on_new:
            failures.append(f"{name}: new row with no baseline entry")

    if failures:
        print(f"check_perf: {len(failures)} regression(s)", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("check_perf: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
