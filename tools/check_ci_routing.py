#!/usr/bin/env python
"""CI test-lane routing guard: fast ignore-list == slow file-list.

The tier-1 suite is split across two CI jobs: ``tier1-fast`` runs pytest
with an ``--ignore=tests/...`` list, and ``tier1-slow`` runs an explicit
file list. The invariant that makes the split safe is *exact
partitioning*: every ``tests/test_*.py`` file runs in exactly one lane —
fast picks up everything not ignored, so the ignore list and the slow
list must be the same set, every listed file must exist, and every test
file on disk that lands in the slow lane must be deliberate.

A new test file is routed correctly by default (fast runs whatever is not
ignored), but two drift modes are silent without this check:

* a file added to the slow job but not to the fast ignore list runs
  *twice* (wasted minutes, and ``-x`` failures point at the wrong lane);
* a file ignored in fast but dropped from slow runs *nowhere* — a test
  that cannot fail.

This script regex-parses the workflow (no yaml dependency in the image)
scoped to each job's block, and fails on any asymmetry. Wired as a step
in the CI ``static`` job; ``--workflow``/``--tests`` exist so the fixture
tests in ``tests/test_ci_routing.py`` can point it at synthetic trees.

Usage: python tools/check_ci_routing.py [--workflow PATH] [--tests DIR]
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FAST_JOB = "tier1-fast"
SLOW_JOB = "tier1-slow"
_IGNORE_RE = re.compile(r"--ignore=(\S+)")
_TESTFILE_RE = re.compile(r"(?<!=)\btests/test_\w+\.py\b")


def job_block(workflow_text: str, job: str) -> str:
    """The text of one job's block: from its key line to the next line at
    the same (2-space) indentation — robust to step reordering, blind to
    yaml semantics we don't need."""
    m = re.search(rf"^  {re.escape(job)}:\s*$", workflow_text, re.M)
    if not m:
        raise SystemExit(f"job {job!r} not found in workflow")
    rest = workflow_text[m.end():]
    nxt = re.search(r"^  \S", rest, re.M)
    return rest[: nxt.start()] if nxt else rest


def fast_ignores(workflow_text: str) -> set:
    """tests/... paths the fast lane ignores."""
    return set(_IGNORE_RE.findall(job_block(workflow_text, FAST_JOB)))


def slow_files(workflow_text: str) -> set:
    """tests/test_*.py paths the slow lane runs explicitly (the
    ``--ignore=`` guard keeps a hypothetical ignore flag inside the slow
    job from counting as a run)."""
    return set(_TESTFILE_RE.findall(job_block(workflow_text, SLOW_JOB)))


def check(workflow_path: str, tests_dir: str) -> list:
    """All routing violations (empty == healthy)."""
    with open(workflow_path, encoding="utf-8") as f:
        wf = f.read()
    ignores = fast_ignores(wf)
    slow = slow_files(wf)
    repo = os.path.dirname(os.path.abspath(tests_dir))
    on_disk = {
        os.path.relpath(p, repo).replace(os.sep, "/")
        for p in glob.glob(os.path.join(tests_dir, "test_*.py"))
    }
    problems = []
    for path in sorted(ignores - slow):
        problems.append(
            f"{path}: ignored by {FAST_JOB} but not run by {SLOW_JOB} — "
            "this file runs in no lane"
        )
    for path in sorted(slow - ignores):
        problems.append(
            f"{path}: run by {SLOW_JOB} but not ignored by {FAST_JOB} — "
            "this file runs twice"
        )
    for path in sorted((ignores | slow) - on_disk):
        problems.append(f"{path}: routed in CI but does not exist")
    for path in sorted(ignores | slow):
        base = path.rsplit("/", 1)[-1]
        if not re.fullmatch(r"test_\w+\.py", base):
            problems.append(
                f"{path}: routed path does not match tests/test_*.py"
            )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--workflow",
        default=os.path.join(REPO_ROOT, ".github", "workflows", "ci.yml"),
        help="workflow file to parse (default: this repo's ci.yml)",
    )
    ap.add_argument(
        "--tests",
        default=os.path.join(REPO_ROOT, "tests"),
        help="tests directory the routed paths must exist in",
    )
    args = ap.parse_args(argv)
    problems = check(args.workflow, args.tests)
    if problems:
        print(
            f"check_ci_routing: {len(problems)} violation(s)",
            file=sys.stderr,
        )
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print("check_ci_routing: OK — fast/slow lanes partition tests exactly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
