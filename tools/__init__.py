# ``tools`` is a package so the static-analysis pass can run as
# ``python -m tools.reprolint`` from the repo root (CI `static` job).
