#!/usr/bin/env python
"""Refresh the committed perf baselines in `benchmarks/baselines/`.

Runs the JSON-emitting benches (`benchmarks/kernel_bench.py`,
`benchmarks/comm_bench.py`, `benchmarks/adaptive_bench.py`, ...) in-process
and rewrites ``benchmarks/baselines/BENCH_kernels.json`` /
``BENCH_comm.json`` / ... — the files the CI ``perf`` job gates new runs
against via `tools/check_perf.py`. Timings are stored alongside the run's
calibration constant, so baselines recorded on one machine remain
comparable (ratio-of-ratios) on another.

After writing each baseline this script *re-runs* the gate against it
(`check_perf --fail-on-new` on the very rows just recorded) and fails on
any remaining "new row, no baseline" line — a half-written or truncated
baseline cannot be committed silently. It also cross-checks that every
baseline file in ``BENCHES`` is actually gated by a ``tools/check_perf.py``
step in `.github/workflows/ci.yml`, so adding a bench here without wiring
its CI gate fails loudly.

Run from the repo root after a deliberate perf-relevant change, and
commit the result:

    PYTHONPATH=src:. python tools/update_baselines.py
"""
from __future__ import annotations

import os
import sys
import tempfile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(REPO_ROOT, "src"), REPO_ROOT]

BENCHES = {
    "kernel_bench": "BENCH_kernels.json",
    "comm_bench": "BENCH_comm.json",
    "adaptive_bench": "BENCH_adaptive.json",
    "fleet_bench": "BENCH_fleet.json",
    "overlap_bench": "BENCH_overlap.json",
}

CI_WORKFLOW = os.path.join(REPO_ROOT, ".github", "workflows", "ci.yml")


def check_ci_gates() -> list:
    """Every BENCHES baseline must appear in a CI check_perf gate step."""
    if not os.path.exists(CI_WORKFLOW):
        return [f"missing workflow {CI_WORKFLOW}"]
    with open(CI_WORKFLOW, encoding="utf-8") as f:
        # collapse yaml '>' line folding so multi-line run: commands
        # compare as the single command line the shell sees
        wf = " ".join(f.read().split())
    problems = []
    for fname in BENCHES.values():
        gate = f"tools/check_perf.py {fname} benchmarks/baselines/{fname}"
        if gate not in wf:
            problems.append(
                f"{fname}: no '{gate}' step in .github/workflows/ci.yml"
            )
    return problems


def main() -> int:
    import importlib

    from benchmarks.common import write_json
    from tools import check_perf

    problems = check_ci_gates()
    for p in problems:
        print(f"update_baselines: CI gate missing — {p}", file=sys.stderr)
    out_dir = os.path.join(REPO_ROOT, "benchmarks", "baselines")
    os.makedirs(out_dir, exist_ok=True)
    failures = len(problems)
    for mod_name, fname in BENCHES.items():
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        rows = mod.run()
        baseline = os.path.join(out_dir, fname)
        write_json(baseline, mod_name, rows)
        # self-check: the rows just timed, gated against the baseline just
        # written, must come back clean with zero "new row" lines — this
        # catches a truncated write or a bench emitting nondeterministic
        # row names before the broken baseline lands in a commit.
        with tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False
        ) as tf:
            current = tf.name
        try:
            write_json(current, mod_name, rows)
            rc = check_perf.main([current, baseline, "--fail-on-new"])
        finally:
            os.unlink(current)
        if rc != 0:
            print(
                f"update_baselines: self-check failed for {fname}",
                file=sys.stderr,
            )
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
