#!/usr/bin/env python
"""Refresh the committed perf baselines in `benchmarks/baselines/`.

Runs the JSON-emitting benches (`benchmarks/kernel_bench.py`,
`benchmarks/comm_bench.py`, `benchmarks/adaptive_bench.py`) in-process and
rewrites ``benchmarks/baselines/BENCH_kernels.json`` /
``BENCH_comm.json`` / ``BENCH_adaptive.json`` — the files the CI ``perf`` job
gates new runs against via `tools/check_perf.py`. Timings are stored
alongside the run's calibration constant, so baselines recorded on one
machine remain comparable (ratio-of-ratios) on another.

Run from the repo root after a deliberate perf-relevant change, and
commit the result:

    PYTHONPATH=src:. python tools/update_baselines.py
"""
from __future__ import annotations

import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(REPO_ROOT, "src"), REPO_ROOT]

BENCHES = {
    "kernel_bench": "BENCH_kernels.json",
    "comm_bench": "BENCH_comm.json",
    "adaptive_bench": "BENCH_adaptive.json",
    "fleet_bench": "BENCH_fleet.json",
}


def main() -> int:
    import importlib

    from benchmarks.common import write_json

    out_dir = os.path.join(REPO_ROOT, "benchmarks", "baselines")
    os.makedirs(out_dir, exist_ok=True)
    for mod_name, fname in BENCHES.items():
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        rows = mod.run()
        write_json(os.path.join(out_dir, fname), mod_name, rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
