"""reprolint command line: ``python -m tools.reprolint [paths...]``.

Exit status 0 when no violations, 1 otherwise. Violations print as
``path:line:col: RPLnnn message`` (one per line, sorted), followed by a
summary. ``--select`` restricts to a comma-separated rule subset (used by
the fixture tests); ``--list-rules`` prints the rule table.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Iterable, List, Optional, Sequence, Tuple

from tools.reprolint import rules as rules_pkg
from tools.reprolint.analysis import ModuleInfo, analyze_traced, collect_array_fields
from tools.reprolint.suppress import apply_suppressions
from tools.reprolint.violations import Violation

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".ruff_cache"}


class FileContext:
    """Per-file bundle handed to each rule's ``check``."""

    def __init__(self, path: str, rel: str, info: ModuleInfo, array_fields):
        self.path = path
        self.rel = rel
        self.info = info
        self.array_fields = array_fields
        self._traced = None

    @property
    def traced_events(self):
        if self._traced is None:
            self._traced = list(
                analyze_traced(self.info, self.array_fields)
            )
        return self._traced


def discover(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                files.append(p)
        elif os.path.isdir(p):
            for root, dirnames, names in os.walk(p):
                dirnames[:] = [
                    d
                    for d in sorted(dirnames)
                    if d not in _SKIP_DIRS and not d.startswith(".")
                ]
                files.extend(
                    os.path.join(root, n)
                    for n in sorted(names)
                    if n.endswith(".py")
                )
        else:
            print(f"reprolint: no such path: {p}", file=sys.stderr)
    return files


def _read_sources(files: Iterable[str]) -> List[Tuple[str, str]]:
    out = []
    for f in files:
        try:
            with open(f, encoding="utf-8") as fh:
                out.append((f, fh.read()))
        except OSError as exc:
            print(f"reprolint: cannot read {f}: {exc}", file=sys.stderr)
    return out


def lint_paths(
    paths: Sequence[str],
    select: Optional[Sequence[str]] = None,
    repo_root: Optional[str] = None,
) -> Tuple[List[Violation], int]:
    """Lint files/directories. Returns (violations, files_scanned).

    The array-field pre-pass always also covers ``<repo_root>/src`` so
    that linting ``tests/`` alone still knows ``CompactState.t`` is an
    array. RPL105 (import-and-inspect) runs only when the scan includes
    files under ``src/repro``.
    """
    repo_root = repo_root or os.getcwd()
    files = discover(paths)
    sources = _read_sources(files)

    prepass = list(sources)
    src_dir = os.path.join(repo_root, "src")
    known = {os.path.abspath(f) for f, _ in sources}
    if os.path.isdir(src_dir):
        extra = [
            f
            for f in discover([src_dir])
            if os.path.abspath(f) not in known
        ]
        prepass.extend(_read_sources(extra))
    array_fields = collect_array_fields(prepass)

    active = set(select) if select else None

    def enabled(rule: str) -> bool:
        return active is None or rule in active

    violations: List[Violation] = []
    scanned = 0
    for path, source in sources:
        rel = os.path.relpath(path, repo_root)
        if rel.startswith(".."):
            rel = path
        try:
            info = ModuleInfo(rel, source)
        except SyntaxError as exc:
            violations.append(
                Violation(
                    rel,
                    exc.lineno or 1,
                    exc.offset or 0,
                    "RPL100",
                    f"syntax error: {exc.msg}",
                )
            )
            continue
        scanned += 1
        ctx = FileContext(path, rel, info, array_fields)
        file_viols: List[Violation] = []
        for mod in rules_pkg.FILE_RULES:
            if enabled(mod.RULE):
                file_viols.extend(mod.check(ctx))
        kept, rpl100 = apply_suppressions(
            rel, source, file_viols, rules_pkg.KNOWN_RULES
        )
        violations.extend(kept)
        if enabled("RPL100"):
            violations.extend(
                Violation(rel, line, col, "RPL100", msg)
                for line, col, msg in rpl100
            )

    touches_repro = any(
        os.path.abspath(f).startswith(
            os.path.join(os.path.abspath(repo_root), "src", "repro")
        )
        for f, _ in sources
    )
    if touches_repro and enabled("RPL105"):
        for mod in rules_pkg.PROJECT_RULES:
            violations.extend(mod.check_project(repo_root))

    return sorted(violations), scanned


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-aware static analysis for JAX/Pallas invariants",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks"]
    )
    parser.add_argument(
        "--select",
        help="comma-separated rule ids to run (default: all)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print rule table and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in sorted(rules_pkg.SUMMARIES):
            print(f"{rule}  {rules_pkg.SUMMARIES[rule]}")
        return 0

    select = (
        [s.strip() for s in args.select.split(",") if s.strip()]
        if args.select
        else None
    )
    violations, scanned = lint_paths(args.paths, select=select)
    for v in violations:
        print(v.render())
    if violations:
        print(
            f"reprolint: {len(violations)} violation(s) in {scanned} file(s)"
        )
        return 1
    print(f"reprolint: clean ({scanned} files)")
    return 0
