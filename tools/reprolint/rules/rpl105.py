"""RPL105 — codec/registry completeness (import-and-inspect).

Runs against the *same binary* the tests import: every concrete ``Codec``
subclass must define ``encode``/``decode``/``wire_bits`` in its own body,
must either override ``encode_fused`` (and declare ``supports_fused =
True``) or explicitly opt out, and must be registered in ``CODECS``.
Every ``Collective`` subclass must define ``reference`` and ``shard`` and
be registered in ``COLLECTIVES``. A codec that quietly inherits the base
``encode_fused`` (which raises) while claiming ``supports_fused = True``
would pass unit tests that never exercise the fused path and then fail
inside a compiled fastpath — exactly the drift this rule exists to stop.
"""
from __future__ import annotations

import inspect
import os
import sys
from typing import Callable, List, Optional

from tools.reprolint.violations import Violation

RULE = "RPL105"
SUMMARY = (
    "Codec/Collective subclass with an incomplete surface or missing "
    "registry entry (import-and-inspect)"
)


def _anchor(cls, rel: Callable[[str], str]):
    try:
        path = inspect.getsourcefile(cls)
        _, line = inspect.getsourcelines(cls)
    except (OSError, TypeError):
        return "<unknown>", 1
    return rel(path), line


def _owns(cls, name: str) -> bool:
    return name in vars(cls)


def check_project(
    repo_root: str, rel: Optional[Callable[[str], str]] = None
) -> List[Violation]:
    rel = rel or (lambda p: os.path.relpath(p, repo_root))
    src = os.path.join(repo_root, "src")
    if os.path.isdir(src) and src not in sys.path:
        sys.path.insert(0, src)
    try:
        from repro.comm.codec import CODECS, Codec
        from repro.comm.collectives import COLLECTIVES, Collective
    except Exception as exc:  # pragma: no cover - import environment issue
        return [
            Violation(
                "src/repro/comm",
                1,
                0,
                RULE,
                f"could not import codec/collective registries: {exc!r}",
            )
        ]

    out: List[Violation] = []

    def walk(base):
        for sub in base.__subclasses__():
            yield sub
            yield from walk(sub)

    registered_codecs = set(type(v) for v in CODECS.values())
    for cls in walk(Codec):
        path, line = _anchor(cls, rel)

        def flag(msg: str, cls=cls, path=path, line=line) -> None:
            out.append(Violation(path, line, 0, RULE, f"{cls.__name__}: {msg}"))

        for meth in ("encode", "decode", "wire_bits"):
            if not any(_owns(k, meth) for k in cls.__mro__[:-1] if k is not Codec):
                flag(
                    f"does not define {meth}() — inherits the abstract "
                    "base implementation"
                )
        owns_fused = any(
            _owns(k, "encode_fused") for k in cls.__mro__[:-1] if k is not Codec
        )
        declares = any(
            _owns(k, "supports_fused") for k in cls.__mro__[:-1] if k is not Codec
        )
        if cls.supports_fused and not owns_fused:
            flag(
                "claims supports_fused=True but inherits the raising base "
                "encode_fused()"
            )
        if owns_fused and not cls.supports_fused:
            flag(
                "defines encode_fused() but supports_fused is False — "
                "dead fused path; set supports_fused=True or drop it"
            )
        if not owns_fused and not declares:
            flag(
                "must set supports_fused=False explicitly (or implement "
                "encode_fused) so fusability is a deliberate choice"
            )
        if cls.__subclasses__():
            continue  # intermediate base; registration applies to leaves
        if cls not in registered_codecs:
            flag("not registered in repro.comm.codec.CODECS")

    registered_colls = set(type(v) for v in COLLECTIVES.values())
    for cls in walk(Collective):
        path, line = _anchor(cls, rel)
        for meth in ("reference", "shard"):
            if not any(
                _owns(k, meth) for k in cls.__mro__[:-1] if k is not Collective
            ):
                out.append(
                    Violation(
                        path,
                        line,
                        0,
                        RULE,
                        f"{cls.__name__}: does not define {meth}()",
                    )
                )
        if not cls.__subclasses__() and cls not in registered_colls:
            out.append(
                Violation(
                    path,
                    line,
                    0,
                    RULE,
                    f"{cls.__name__}: not registered in "
                    "repro.comm.collectives.COLLECTIVES",
                )
            )
    return out
