"""Rule registry. Each per-file rule module exposes ``RULE`` (stable id),
``SUMMARY``, and ``check(ctx) -> list[Violation]``. RPL105 is a
project-level import-and-inspect pass with its own entry point.
"""
from __future__ import annotations

from tools.reprolint.rules import (
    rpl101,
    rpl102,
    rpl103,
    rpl104,
    rpl105,
    rpl106,
)

FILE_RULES = (rpl101, rpl102, rpl103, rpl104, rpl106)
PROJECT_RULES = (rpl105,)

KNOWN_RULES = frozenset(
    {"RPL100"}
    | {m.RULE for m in FILE_RULES}
    | {m.RULE for m in PROJECT_RULES}
)

SUMMARIES = {
    "RPL100": "unused or unknown `# reprolint: disable=` suppression",
    **{m.RULE: m.SUMMARY for m in FILE_RULES},
    **{m.RULE: m.SUMMARY for m in PROJECT_RULES},
}
