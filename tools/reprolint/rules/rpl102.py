"""RPL102 — shard-axis discipline.

Every *string literal* axis name reaching ``lax.psum`` / ``pmean`` /
``all_gather`` / ``ppermute`` (and friends) must be declared by a mesh or
``shard_map`` constructed in the same module; axis names resolved from
function parameters or enclosing-scope bindings always pass. This catches
a hardcoded ``"data"`` leaking into ``repro.comm.collectives`` — library
code must receive axis names from its caller so the same collective runs
under any mesh naming (the renamed-axis smoke test in
``tests/test_guards.py`` is the runtime twin of this rule).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from tools.reprolint.analysis import ModuleInfo, enclosing_functions
from tools.reprolint.violations import Violation

RULE = "RPL102"
SUMMARY = (
    "hardcoded axis-name literal passed to a lax collective without a "
    "same-module mesh/shard_map declaring it"
)

COLLECTIVES = {
    "psum",
    "pmean",
    "pmax",
    "pmin",
    "all_gather",
    "ppermute",
    "all_to_all",
    "psum_scatter",
    "axis_index",
    "axis_size",
}

# callables whose string arguments *declare* mesh axis names
_DECLARERS = {"make_mesh", "Mesh", "AbstractMesh", "shard_map", "make_jax_mesh"}


def _axis_arg(call: ast.Call, fn_last: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "axis_name":
            return kw.value
    pos = 0 if fn_last in ("axis_index", "axis_size") else 1
    if len(call.args) > pos:
        return call.args[pos]
    return None


def _declared_axes(info: ModuleInfo) -> Set[str]:
    axes: Set[str] = set()
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        resolved = info.resolve(node.func) or ""
        if resolved.rsplit(".", 1)[-1] not in _DECLARERS:
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                axes.add(sub.value)
    return axes


def _literals(
    expr: ast.AST,
    info: ModuleInfo,
    scope_params: Set[str],
    depth: int = 0,
) -> List[Tuple[ast.AST, str]]:
    """Collect (node, axis_literal) pairs provably hardcoded in ``expr``.
    Anything resolving to a parameter or an unknown origin contributes
    nothing (conservative)."""
    if depth > 4:
        return []
    if isinstance(expr, ast.Constant):
        if isinstance(expr.value, str):
            return [(expr, expr.value)]
        return []
    if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for e in expr.elts:
            out.extend(_literals(e, info, scope_params, depth + 1))
        return out
    if isinstance(expr, ast.Name):
        if expr.id in scope_params:
            return []
        if expr.id in info.constants:
            val = info.constants[expr.id]
            vals = val if isinstance(val, tuple) else (val,)
            return [
                (expr, v) for v in vals if isinstance(v, str)
            ]
        bound = info.assignments.get(expr.id)
        if bound is not None and not isinstance(bound, ast.Name):
            return _literals(bound, info, scope_params, depth + 1)
        return []
    if isinstance(expr, ast.Starred):
        return _literals(expr.value, info, scope_params, depth + 1)
    if isinstance(expr, ast.Subscript):
        return _literals(expr.value, info, scope_params, depth + 1)
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        return _literals(expr.left, info, scope_params, depth + 1) + _literals(
            expr.right, info, scope_params, depth + 1
        )
    if isinstance(expr, ast.Call):
        resolved = info.resolve(expr.func) or ""
        if resolved.rsplit(".", 1)[-1] in ("tuple", "list", "sorted") and expr.args:
            return _literals(expr.args[0], info, scope_params, depth + 1)
        return []
    return []


def check(ctx) -> List[Violation]:
    info = ctx.info
    declared = _declared_axes(info)
    scopes = enclosing_functions(info.tree)
    out: List[Violation] = []
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        resolved = info.resolve(node.func) or ""
        last = resolved.rsplit(".", 1)[-1]
        if last not in COLLECTIVES or ".lax." not in f".{resolved}":
            continue
        axis = _axis_arg(node, last)
        if axis is None:
            continue
        params: Set[str] = set()
        for fn in scopes.get(id(node), []):
            a = fn.args
            for arg in (
                a.posonlyargs + a.args + a.kwonlyargs
            ):
                params.add(arg.arg)
            for var in (a.vararg, a.kwarg):
                if var is not None:
                    params.add(var.arg)
        for lit_node, name in _literals(axis, info, params):
            if name in declared:
                continue
            out.append(
                Violation(
                    ctx.rel,
                    lit_node.lineno,
                    lit_node.col_offset,
                    RULE,
                    f"hardcoded axis name '{name}' passed to lax.{last} — "
                    "thread axis names from the caller (parameter or "
                    "shard_map axis_names); no mesh in this module "
                    "declares it",
                )
            )
    return out
