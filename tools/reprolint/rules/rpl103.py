"""RPL103 — Pallas kernel constraints.

Applies to modules that import ``jax.experimental.pallas`` (in the repo:
``src/repro/kernels/``). Checks:

* ``BlockSpec`` tile dims must be multiples of the f32 (sublane, lane)
  = (8, 128) TPU layout. Dims equal to 1 are exempt — degenerate
  per-tile blocks like ``(1, m)`` candidate outputs and ``(1, 1)``
  scalar accumulators are legal and idiomatic. Dims that cannot be
  constant-folded from module-level constants are skipped.
* no ``float64`` anywhere in a kernel module (TPU has no f64; the repo's
  exactness certificate is defined for f32 state).
* no Python ``for ... in range(<tracer>)`` inside a kernel body — loop
  bounds must be compile-time constants (bind them as keyword-only
  ``functools.partial`` parameters, as ``fused_encode.py`` does).
* literal ``pl.program_id(axis)`` must be < the maximum grid rank of any
  ``pallas_call`` in the module.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from tools.reprolint.analysis import fold
from tools.reprolint.violations import Violation

RULE = "RPL103"
SUMMARY = (
    "Pallas kernel constraint: BlockSpec tiling, float64, "
    "tracer-range loop, or program_id axis out of grid rank"
)

SUBLANE, LANE = 8, 128


def _is_pallas_module(info) -> bool:
    return any(
        "jax.experimental.pallas" in origin
        for origin in info.aliases.values()
    )


def _grid_rank(call: ast.Call) -> Optional[int]:
    for kw in call.keywords:
        if kw.arg == "grid":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return len(kw.value.elts)
            return 1  # scalar grid
    return None


def check(ctx) -> List[Violation]:
    info = ctx.info
    if not _is_pallas_module(info):
        return []
    out: List[Violation] = []

    max_rank = 0
    any_grid = False
    for node in ast.walk(info.tree):
        if isinstance(node, ast.Call):
            resolved = info.resolve(node.func) or ""
            if resolved.rsplit(".", 1)[-1] == "pallas_call":
                rank = _grid_rank(node)
                if rank is not None:
                    any_grid = True
                    max_rank = max(max_rank, rank)

    for node in ast.walk(info.tree):
        if isinstance(node, ast.Attribute):
            resolved = info.resolve(node) or ""
            if resolved in ("jax.numpy.float64", "numpy.float64"):
                out.append(
                    Violation(
                        ctx.rel,
                        node.lineno,
                        node.col_offset,
                        RULE,
                        "float64 in a Pallas kernel module — TPU kernels "
                        "and the exactness certificate are f32-only",
                    )
                )
        if isinstance(node, ast.Constant) and node.value == "float64":
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    node.col_offset,
                    RULE,
                    "dtype string 'float64' in a Pallas kernel module — "
                    "TPU kernels are f32-only",
                )
            )
        if not isinstance(node, ast.Call):
            continue
        resolved = info.resolve(node.func) or ""
        last = resolved.rsplit(".", 1)[-1]
        if last == "BlockSpec":
            shape = None
            if node.args:
                shape = node.args[0]
            for kw in node.keywords:
                if kw.arg == "block_shape":
                    shape = kw.value
            if shape is not None:
                try:
                    dims = fold(shape, info.constants)
                except ValueError:
                    dims = None
                if isinstance(dims, tuple) and len(dims) >= 2:
                    checks = (
                        (dims[-1], LANE, "minor (lane)"),
                        (dims[-2], SUBLANE, "second-minor (sublane)"),
                    )
                    for dim, mult, what in checks:
                        if (
                            isinstance(dim, int)
                            and dim != 1
                            and dim % mult != 0
                        ):
                            out.append(
                                Violation(
                                    ctx.rel,
                                    node.lineno,
                                    node.col_offset,
                                    RULE,
                                    f"BlockSpec {what} dim {dim} is not a "
                                    f"multiple of {mult} (f32 tile is "
                                    f"({SUBLANE}, {LANE}); dim 1 is "
                                    "exempt)",
                                )
                            )
        elif last == "program_id" and any_grid:
            if node.args and isinstance(node.args[0], ast.Constant):
                axis = node.args[0].value
                if isinstance(axis, int) and axis >= max_rank:
                    out.append(
                        Violation(
                            ctx.rel,
                            node.lineno,
                            node.col_offset,
                            RULE,
                            f"pl.program_id({axis}) but the largest grid "
                            f"in this module has rank {max_rank}",
                        )
                    )

    for tf, events in ctx.traced_events:
        if tf.kind != "pallas":
            continue
        for ev in events:
            if ev.kind == "range_loop":
                out.append(
                    Violation(
                        ctx.rel,
                        ev.node.lineno,
                        ev.node.col_offset,
                        RULE,
                        "Python loop over a tracer-dependent range inside "
                        f"kernel '{tf.fn.name}' — bind the bound as a "
                        "static keyword-only parameter "
                        "(functools.partial) or use lax.fori_loop",
                    )
                )
    return out
