"""RPL101 — tracer-unsafe Python control flow.

``if``/``while``/``assert`` (and conditional expressions) whose test
data-flows from array parameters inside a ``@jax.jit`` / ``shard_map`` /
Pallas-wrapped function either fail at trace time with a concretization
error or — worse, with ``static_argnums`` plumbing — silently retrace per
value. Branch on trace-time config instead, or use ``jnp.where`` /
``lax.cond`` / ``lax.select`` for value-dependent logic.
"""
from __future__ import annotations

from typing import List

from tools.reprolint.violations import Violation

RULE = "RPL101"
SUMMARY = (
    "Python if/while/assert on a value derived from traced arrays "
    "inside a jit/shard_map/pallas function"
)

_WHAT = {
    "if": "`if` statement",
    "while": "`while` loop",
    "assert": "`assert`",
    "ifexp": "conditional expression",
}

_HINT = {
    "if": "use jnp.where or lax.cond",
    "while": "use lax.while_loop or lax.fori_loop",
    "assert": "use checkify or debug.check, or assert on static shapes only",
    "ifexp": "use jnp.where or lax.select",
}


def check(ctx) -> List[Violation]:
    out = []
    for tf, events in ctx.traced_events:
        for ev in events:
            if ev.kind not in _WHAT:
                continue
            node = ev.node
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    node.col_offset,
                    RULE,
                    f"{_WHAT[ev.kind]} on a tracer-derived value inside "
                    f"{tf.kind}-traced function '{tf.fn.name}' — "
                    f"{_HINT[ev.kind]}",
                )
            )
    return out
