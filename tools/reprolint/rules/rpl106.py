"""RPL106 — SparsifierState slot discipline.

``SparsifierState`` reuses its slots across sparsifier kinds:
``a_prev`` holds RegTop-k's accepted gradient, DGC's momentum buffer,
and CoordTopK's common-knowledge staleness counters; ``s_prev`` and
``eps`` are folded differently per kind. Code outside
``repro.core.sparsify`` cannot know which interpretation is live for
the configured kind, so a direct field-write — constructing a
``SparsifierState`` from loose arrays or ``._replace``-ing the
kind-overloaded slots — silently corrupts state for every kind except
the one the writer had in mind (the dropped-worker rewrite bug fixed
alongside this rule). Route such rewrites through the owning
``Sparsifier`` hooks (``on_dropped`` / ``on_wire_residual``) instead.

Flags, in every file except the owning module
``src/repro/core/sparsify.py``:

* any ``SparsifierState(...)`` constructor call;
* any ``._replace(...)`` call passing ``a_prev=`` or ``s_prev=``
  keywords (slot names unique to ``SparsifierState`` in this repo;
  a bare ``eps=`` replace is not flagged because ``CompactState``
  shares that field name and owns its own error accumulator).
"""
from __future__ import annotations

import ast
from typing import List

from tools.reprolint.violations import Violation

RULE = "RPL106"
SUMMARY = (
    "SparsifierState slot write outside repro.core.sparsify — use the "
    "Sparsifier hooks (on_dropped / on_wire_residual)"
)

OWNER = "src/repro/core/sparsify.py"
_UNIQUE_SLOTS = frozenset({"a_prev", "s_prev"})


def check(ctx) -> List[Violation]:
    if ctx.rel.replace("\\", "/") == OWNER:
        return []
    info = ctx.info
    out: List[Violation] = []
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        resolved = info.resolve(node.func) or ""
        if resolved.rsplit(".", 1)[-1] == "SparsifierState":
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    node.col_offset,
                    RULE,
                    "SparsifierState constructed outside "
                    "repro.core.sparsify — slot meaning is "
                    "kind-specific (a_prev is momentum for DGC, "
                    "staleness counters for CoordTopK); use the "
                    "Sparsifier hooks instead",
                )
            )
            continue
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "_replace"
        ):
            hit = sorted(
                kw.arg
                for kw in node.keywords
                if kw.arg in _UNIQUE_SLOTS
            )
            if hit:
                out.append(
                    Violation(
                        ctx.rel,
                        node.lineno,
                        node.col_offset,
                        RULE,
                        f"._replace({', '.join(h + '=' for h in hit)}...) "
                        "rewrites kind-overloaded SparsifierState slots "
                        "outside repro.core.sparsify — use the "
                        "Sparsifier hooks instead",
                    )
                )
    return out
