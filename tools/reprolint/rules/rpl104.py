"""RPL104 — recompilation hazards.

* unhashable (list/dict/set) or array-valued defaults on jitted
  functions: array defaults bake a fresh constant per trace and mutable
  defaults are shared across calls;
* ``static_argnums`` / ``static_argnames`` pointing at array-annotated
  parameters: every distinct array value forces a retrace;
* f-strings or dict-literal keys derived from traced values inside a
  traced function: hashing/formatting a tracer concretizes it;
* ``jax.jit(fn)`` on a plain function name inside a loop: each
  iteration builds a fresh wrapper with an empty compilation cache
  (lambdas are exempt — rebinding a lambda per iteration is sometimes
  deliberate; hoisting a *named* function never loses anything).
"""
from __future__ import annotations

import ast
from typing import List

from tools.reprolint.analysis import ARRAY_ANN_RE
from tools.reprolint.violations import Violation

RULE = "RPL104"
SUMMARY = (
    "recompilation hazard: bad jit defaults, static_argnums on arrays, "
    "tracer-keyed hashing, or jit-in-loop"
)

_ARRAY_CTORS = {"array", "asarray", "zeros", "ones", "full", "arange", "eye"}


def _bad_default(node: ast.AST, info) -> str:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return "unhashable (mutable) default"
    if isinstance(node, ast.Call):
        resolved = info.resolve(node.func) or ""
        parts = resolved.rsplit(".", 1)
        if parts[-1] in _ARRAY_CTORS and resolved.startswith(
            ("jax.numpy", "numpy", "jax.")
        ):
            return "array-valued default"
    return ""


def _param_names(fn: ast.FunctionDef) -> List[ast.arg]:
    a = fn.args
    return a.posonlyargs + a.args


def check(ctx) -> List[Violation]:
    info = ctx.info
    out: List[Violation] = []

    for tf, events in ctx.traced_events:
        fn = tf.fn
        if tf.kind == "jit":
            a = fn.args
            positional = a.posonlyargs + a.args
            paired = [
                *zip(reversed(positional), reversed(a.defaults), strict=False),
                *(
                    (arg, d)
                    for arg, d in zip(a.kwonlyargs, a.kw_defaults, strict=True)
                    if d is not None
                ),
            ]
            for arg, default in paired:
                why = _bad_default(default, info)
                if why:
                    out.append(
                        Violation(
                            ctx.rel,
                            default.lineno,
                            default.col_offset,
                            RULE,
                            f"{why} for parameter '{arg.arg}' of jitted "
                            f"function '{fn.name}' — pass it explicitly "
                            "or build it inside the function",
                        )
                    )
            # static_argnums / static_argnames on array-annotated params
            via = tf.via
            if isinstance(via, ast.Call):
                for kw in via.keywords:
                    if kw.arg not in ("static_argnums", "static_argnames"):
                        continue
                    vals = (
                        kw.value.elts
                        if isinstance(kw.value, (ast.Tuple, ast.List))
                        else [kw.value]
                    )
                    params = _param_names(fn)
                    for v in vals:
                        if not isinstance(v, ast.Constant):
                            continue
                        arg = None
                        if isinstance(v.value, int) and 0 <= v.value < len(
                            params
                        ):
                            arg = params[v.value]
                        elif isinstance(v.value, str):
                            allp = params + fn.args.kwonlyargs
                            arg = next(
                                (p for p in allp if p.arg == v.value), None
                            )
                        if (
                            arg is not None
                            and arg.annotation is not None
                            and ARRAY_ANN_RE.search(
                                ast.unparse(arg.annotation)
                            )
                        ):
                            out.append(
                                Violation(
                                    ctx.rel,
                                    v.lineno,
                                    v.col_offset,
                                    RULE,
                                    f"{kw.arg} marks array parameter "
                                    f"'{arg.arg}' of '{fn.name}' static — "
                                    "every distinct value retraces",
                                )
                            )
        for ev in events:
            if ev.kind == "fstring":
                out.append(
                    Violation(
                        ctx.rel,
                        ev.node.lineno,
                        ev.node.col_offset,
                        RULE,
                        "f-string interpolates a traced value inside "
                        f"'{fn.name}' — formatting concretizes the "
                        "tracer; use jax.debug.print",
                    )
                )
            elif ev.kind == "dict_key":
                out.append(
                    Violation(
                        ctx.rel,
                        ev.node.lineno,
                        ev.node.col_offset,
                        RULE,
                        "dict key derived from a traced value inside "
                        f"'{fn.name}' — hashing a tracer concretizes it",
                    )
                )

    # jax.jit(named_fn) inside a loop (dedupe nested-loop double walks)
    seen = set()
    for node in ast.walk(info.tree):
        if not isinstance(node, (ast.For, ast.While)):
            continue
        for sub in ast.walk(node):
            if id(sub) in seen:
                continue
            if not isinstance(sub, ast.Call):
                continue
            if info.wrapper_kind(sub.func) != "jit":
                continue
            if sub.args and isinstance(sub.args[0], ast.Name):
                seen.add(id(sub))
                out.append(
                    Violation(
                        ctx.rel,
                        sub.lineno,
                        sub.col_offset,
                        RULE,
                        f"jax.jit({sub.args[0].id}) inside a loop builds a "
                        "fresh wrapper (empty compile cache) every "
                        "iteration — hoist the jit out of the loop",
                    )
                )
    return out
