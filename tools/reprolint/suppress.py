"""Same-line ``# reprolint: disable=RPLnnn`` suppressions.

Comments are located with :mod:`tokenize` (not a per-line regex) so that
example suppressions *inside string literals* — fixture sources embedded in
the rule test modules — are never mistaken for live suppressions. Every
suppression must match at least one violation on its line or it is itself
reported as RPL100, which keeps stale suppressions from hiding future
regressions.
"""
from __future__ import annotations

import io
import re
import tokenize
from typing import Dict, List, Set, Tuple

SUPPRESS_RE = re.compile(r"reprolint:\s*disable=([A-Z0-9,\s]+)")
CODE_RE = re.compile(r"^RPL\d{3}$")


def parse_suppressions(source: str) -> Dict[int, Tuple[Set[str], int]]:
    """Map line number -> (rule codes, comment column) for every real
    ``# reprolint: disable=...`` comment in ``source``."""
    out: Dict[int, Tuple[Set[str], int]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
            out[tok.start[0]] = (codes, tok.start[1])
    except tokenize.TokenError:
        pass
    return out


def apply_suppressions(path, source, violations, known_rules):
    """Filter ``violations`` through the file's suppressions. Returns
    (kept_violations, rpl100_list) where rpl100_list holds (line, col,
    message) entries for unused or unknown suppressions."""
    supp = parse_suppressions(source)
    used: Dict[int, Set[str]] = {line: set() for line in supp}
    kept = []
    for v in violations:
        codes, _ = supp.get(v.line, (set(), 0))
        if v.rule in codes:
            used[v.line].add(v.rule)
        else:
            kept.append(v)
    rpl100: List[Tuple[int, int, str]] = []
    for line, (codes, col) in sorted(supp.items()):
        for code in sorted(codes):
            if not CODE_RE.match(code) or code not in known_rules:
                rpl100.append(
                    (line, col, f"unknown rule '{code}' in suppression")
                )
            elif code not in used[line]:
                rpl100.append(
                    (
                        line,
                        col,
                        f"unused suppression for {code} "
                        "(no matching violation on this line)",
                    )
                )
    return kept, rpl100
