"""reprolint: repo-aware static analysis for JAX/Pallas invariants.

Rules
-----
* RPL100 — unused/unknown suppression (meta-rule)
* RPL101 — tracer-unsafe Python control flow in traced functions
* RPL102 — shard-axis discipline for ``lax`` collectives
* RPL103 — Pallas kernel constraints (tiling, f64, tracer ranges, grid)
* RPL104 — recompilation hazards (defaults, static_argnums, tracer keys)
* RPL105 — codec/collective registry completeness (import-and-inspect)

Run ``python -m tools.reprolint src tests benchmarks`` from the repo
root; suppress a single line with ``# reprolint: disable=RPLnnn``.
See ``docs/static_analysis.md`` for the full rule reference.
"""
from tools.reprolint.cli import lint_paths, main
from tools.reprolint.violations import Violation

__all__ = ["Violation", "lint_paths", "main"]
