"""Violation record shared by all reprolint rules."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
