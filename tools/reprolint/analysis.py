"""Shared AST analysis for reprolint: alias resolution, constant folding,
traced-function discovery, and the tracer-taint engine.

The rules (``tools/reprolint/rules/``) are thin consumers of this module.
Everything here is *conservative by construction*: when a value's origin
cannot be resolved statically the engine degrades to "unknown/static" so
rules only fire on provable hazards — a repo-wide lint that cries wolf is
a repo-wide lint that gets disabled.

Key repo-aware ingredient: :func:`collect_array_fields` scans class bodies
for fields annotated as JAX arrays (``eps: jax.Array`` on ``CompactState``
and friends), so ``st.t`` taints as a tracer while ``scfg.kind`` — an
attribute on the *same* untyped parameter — stays static. That distinction
is what keeps RPL101 useful on idiomatic code that threads config
dataclasses through ``shard_map`` bodies.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

# taint lattice: STATIC < MAYBE < TRACER
STATIC, MAYBE, TRACER = 0, 1, 2

JIT_NAMES = {"jit", "pjit"}
SHARD_NAMES = {"shard_map"}
PALLAS_NAMES = {"pallas_call"}
# tracing higher-order functions whose callee argument is traced with
# abstract values exactly like a jitted function's parameters.
HOF_NAMES = {
    "vmap",
    "grad",
    "value_and_grad",
    "scan",
    "cond",
    "while_loop",
    "fori_loop",
    "checkpoint",
    "remat",
}

ARRAY_ANN_RE = re.compile(r"\b(Array|ndarray|ArrayLike)\b")

# attributes of an array that are static python values at trace time
STATIC_ATTRS = {
    "shape",
    "ndim",
    "dtype",
    "size",
    "itemsize",
    "sharding",
    "weak_type",
    "aval",
}

# methods that return an array when called on an array-ish receiver —
# these promote a MAYBE receiver to TRACER (``g[0].reshape(...)``)
ARRAY_METHODS = {
    "reshape",
    "astype",
    "ravel",
    "flatten",
    "squeeze",
    "transpose",
    "sum",
    "mean",
    "max",
    "min",
    "prod",
    "cumsum",
    "cumprod",
    "clip",
    "round",
    "take",
    "dot",
    "argmax",
    "argmin",
    "sort",
    "argsort",
    "any",
    "all",
    "conj",
    "copy",
    "add",
    "set",
    "multiply",
    "get",
}

# builtins that return trace-time-static python values
CONCRETIZING = {"len", "isinstance", "type", "getattr", "hasattr", "id", "repr"}

JAX_NAMESPACES = ("jax", "jax.numpy", "jax.lax", "jax.nn", "jax.random")


def fold(node: ast.AST, env: Dict[str, object]):
    """Best-effort constant folding: literals, names bound in ``env``,
    tuples/lists, and int arithmetic. Raises ValueError when unfoldable."""
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in env:
            return env[node.id]
        raise ValueError(f"unbound name {node.id}")
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(fold(e, env) for e in node.elts)
    if isinstance(node, ast.BinOp):
        left, right = fold(node.left, env), fold(node.right, env)
        ops = {
            ast.Add: lambda a, b: a + b,
            ast.Sub: lambda a, b: a - b,
            ast.Mult: lambda a, b: a * b,
            ast.FloorDiv: lambda a, b: a // b,
            ast.Mod: lambda a, b: a % b,
            ast.Pow: lambda a, b: a**b,
        }
        op = ops.get(type(node.op))
        if op is None:
            raise ValueError("unfoldable operator")
        return op(left, right)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -fold(node.operand, env)
    raise ValueError(f"unfoldable node {type(node).__name__}")


class ModuleInfo:
    """One parsed file: import aliases, module constants, local defs."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.aliases: Dict[str, str] = {}  # local name -> dotted origin
        self.constants: Dict[str, object] = {}  # module-level literal consts
        self.functions: Dict[str, ast.FunctionDef] = {}  # name -> def (any scope)
        self.assignments: Dict[str, ast.AST] = {}  # simple name -> RHS node
        self._collect()

    def _collect(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name):
                    self.assignments.setdefault(t.id, node.value)
        # module-level constants, folded in statement order so derived
        # constants (BLOCK = (SUBLANES, LANES)) resolve too.
        for stmt in self.tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                t = stmt.targets[0]
                if isinstance(t, ast.Name):
                    try:
                        self.constants[t.id] = fold(stmt.value, self.constants)
                    except ValueError:
                        pass

    # -- name resolution ---------------------------------------------------
    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted origin of a Name/Attribute chain, through import aliases:
        ``pl.pallas_call`` -> ``jax.experimental.pallas.pallas_call``."""
        if isinstance(node, ast.Name):
            return self.aliases.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            return f"{base}.{node.attr}" if base else None
        return None

    def wrapper_kind(self, node: ast.AST) -> Optional[str]:
        """Classify a decorator / callee expression as a tracing wrapper:
        'jit' | 'shard_map' | 'pallas' | 'hof' | None. Handles
        ``functools.partial(jax.jit, ...)`` decorator factories."""
        target = node
        if isinstance(node, ast.Call):
            r = self.resolve(node.func)
            if r and r.rsplit(".", 1)[-1] == "partial" and node.args:
                target = node.args[0]
            else:
                target = node.func
        r = self.resolve(target)
        if not r:
            return None
        last = r.rsplit(".", 1)[-1]
        if last in JIT_NAMES:
            return "jit"
        if last in SHARD_NAMES:
            return "shard_map"
        if last in PALLAS_NAMES:
            return "pallas"
        if last in HOF_NAMES:
            return "hof"
        return None

    def local_def(self, node: ast.AST) -> Optional[ast.FunctionDef]:
        """Resolve an expression to a FunctionDef in this module: a bare
        name, a name bound to ``functools.partial(fn, ...)``, or a
        ``partial(fn, ...)`` call inline."""
        for _ in range(4):  # bounded chase through simple bindings
            if isinstance(node, ast.Call):
                r = self.resolve(node.func)
                if r and r.rsplit(".", 1)[-1] == "partial" and node.args:
                    node = node.args[0]
                    continue
                return None
            if isinstance(node, ast.Name):
                if node.id in self.functions:
                    return self.functions[node.id]
                if node.id in self.assignments:
                    node = self.assignments[node.id]
                    continue
                return None
            return None
        return None


@dataclasses.dataclass
class TracedFn:
    fn: ast.FunctionDef
    kind: str  # 'jit' | 'shard_map' | 'pallas' | 'hof'
    via: ast.AST  # decorator or wrapper Call node


def traced_functions(info: ModuleInfo) -> List[TracedFn]:
    """Functions whose bodies execute under JAX tracing: decorated with a
    tracing wrapper, or locally defined and passed to one."""
    found: Dict[int, TracedFn] = {}
    for node in ast.walk(info.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                kind = info.wrapper_kind(dec)
                if kind and kind != "hof":
                    found.setdefault(id(node), TracedFn(node, kind, dec))
        elif isinstance(node, ast.Call):
            kind = info.wrapper_kind(node.func)
            if kind and node.args:
                fn = info.local_def(node.args[0])
                if fn is not None:
                    k = "jit" if kind == "hof" else kind
                    found.setdefault(id(fn), TracedFn(fn, k, node))
    return list(found.values())


def collect_array_fields(sources: Sequence[Tuple[str, str]]) -> Set[str]:
    """Project pre-pass: names of class fields annotated as JAX arrays
    (``eps: jax.Array``) across all given ``(path, source)`` pairs — the
    repo-aware taint signal for attribute access on untyped parameters."""
    fields: Set[str] = set()
    for path, src in sources:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for stmt in node.body:
                if (
                    isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and ARRAY_ANN_RE.search(ast.unparse(stmt.annotation))
                ):
                    fields.add(stmt.target.id)
    return fields


@dataclasses.dataclass
class Event:
    """One taint-relevant site inside a traced function."""

    kind: str  # 'if' | 'while' | 'assert' | 'ifexp' | 'fstring' |
    #            'dict_key' | 'range_loop'
    node: ast.AST
    fn: ast.FunctionDef


def _param_taints(
    info: ModuleInfo, fn: ast.FunctionDef, kind: str
) -> Dict[str, int]:
    args = fn.args
    taints: Dict[str, int] = {}
    if kind == "pallas":
        # positional params are Refs (reads are tracers); keyword-only
        # params are compile-time constants bound via functools.partial.
        for a in args.posonlyargs + args.args:
            taints[a.arg] = TRACER
        for a in args.kwonlyargs:
            taints[a.arg] = STATIC
        return taints
    positional = args.posonlyargs + args.args
    defaults: Dict[str, ast.AST] = {}
    for a, d in zip(reversed(positional), reversed(args.defaults), strict=False):
        defaults[a.arg] = d
    for a, d in zip(args.kwonlyargs, args.kw_defaults, strict=True):
        if d is not None:
            defaults[a.arg] = d
    for a in positional + args.kwonlyargs:
        if a.arg == "self":
            taints[a.arg] = STATIC
        elif a.annotation is not None:
            ann = ast.unparse(a.annotation)
            taints[a.arg] = TRACER if ARRAY_ANN_RE.search(ann) else STATIC
        elif isinstance(defaults.get(a.arg), ast.Constant):
            taints[a.arg] = STATIC
        else:
            taints[a.arg] = MAYBE
    for vararg in (args.vararg, args.kwarg):
        if vararg is not None:
            taints[vararg.arg] = MAYBE
    return taints


class FunctionTaint:
    """Single forward pass over one traced function's body, tracking a
    name -> taint environment and emitting :class:`Event`s for every
    tracer-dependent control-flow / hashing site."""

    def __init__(
        self,
        info: ModuleInfo,
        fn: ast.FunctionDef,
        kind: str,
        array_fields: Set[str],
    ):
        self.info = info
        self.fn = fn
        self.kind = kind
        self.array_fields = array_fields
        self.env = _param_taints(info, fn, kind)
        self.events: List[Event] = []

    # -- expression taint --------------------------------------------------
    def etaint(self, node: ast.AST) -> int:
        if node is None or isinstance(node, ast.Constant):
            return STATIC
        if isinstance(node, ast.Name):
            return self.env.get(node.id, STATIC)
        if isinstance(node, ast.Attribute):
            base = self.etaint(node.value)
            if node.attr in STATIC_ATTRS:
                return STATIC
            if base == STATIC:
                return STATIC
            if node.attr in self.array_fields:
                return TRACER
            # attribute of an actual array (.T, .at, .real) stays an array;
            # attribute of an *untyped* maybe-array is config access.
            return TRACER if base == TRACER else STATIC
        if isinstance(node, (ast.Subscript, ast.Starred)):
            return self.etaint(node.value)
        if isinstance(node, ast.Call):
            return self._call_taint(node)
        if isinstance(node, ast.BinOp):
            return max(self.etaint(node.left), self.etaint(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.etaint(node.operand)
        if isinstance(node, ast.BoolOp):
            return max(self.etaint(v) for v in node.values)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return STATIC
            t = max(
                self.etaint(node.left),
                max(self.etaint(c) for c in node.comparators),
            )
            # comparison against a string literal is config dispatch, not
            # arithmetic on array values — cap below the flagging level.
            operands = [node.left, *node.comparators]
            if any(
                isinstance(o, ast.Constant) and isinstance(o.value, str)
                for o in operands
            ):
                return min(t, MAYBE)
            return t
        if isinstance(node, ast.IfExp):
            if self.etaint(node.test) == TRACER:
                self.events.append(Event("ifexp", node, self.fn))
            return max(self.etaint(node.body), self.etaint(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return max((self.etaint(e) for e in node.elts), default=STATIC)
        if isinstance(node, ast.Dict):
            for key in node.keys:
                if key is not None and self.etaint(key) == TRACER:
                    self.events.append(Event("dict_key", key, self.fn))
            return max(
                (self.etaint(v) for v in node.values), default=STATIC
            )
        if isinstance(node, ast.JoinedStr):
            for part in node.values:
                if (
                    isinstance(part, ast.FormattedValue)
                    and self.etaint(part.value) == TRACER
                ):
                    self.events.append(Event("fstring", part, self.fn))
            return STATIC
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self._comp_taint(node, node.elt)
        if isinstance(node, ast.DictComp):
            return self._comp_taint(node, node.value)
        if isinstance(node, ast.NamedExpr):
            t = self.etaint(node.value)
            self._bind(node.target, t)
            return t
        if isinstance(node, ast.Lambda):
            return STATIC
        return STATIC

    def _comp_taint(self, node: ast.AST, elt: ast.AST) -> int:
        saved = dict(self.env)
        try:
            for gen in node.generators:
                self._bind(gen.target, self.etaint(gen.iter))
            return self.etaint(elt)
        finally:
            self.env = saved

    def _call_taint(self, node: ast.Call) -> int:
        resolved = self.info.resolve(node.func) or ""
        last = resolved.rsplit(".", 1)[-1]
        arg_taint = max(
            (
                self.etaint(a)
                for a in [*node.args, *[kw.value for kw in node.keywords]]
            ),
            default=STATIC,
        )
        if last in CONCRETIZING and isinstance(node.func, ast.Name):
            return STATIC
        if isinstance(node.func, ast.Attribute):
            recv = self.etaint(node.func.value)
            if node.func.attr in ARRAY_METHODS and recv >= MAYBE:
                return TRACER
            return max(recv if recv == TRACER else STATIC, arg_taint)
        if resolved.startswith(JAX_NAMESPACES):
            return arg_taint
        return arg_taint

    # -- statement walk ----------------------------------------------------
    def _bind(self, target: ast.AST, taint: int) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = taint
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, taint)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, taint)
        # Subscript / Attribute targets mutate containers; no env change.

    def run(self) -> List[Event]:
        for stmt in self.fn.body:
            self._visit(stmt)
        return self.events

    def _visit(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested defs are discovered & analyzed independently
        if isinstance(stmt, ast.Assign):
            t = self.etaint(stmt.value)
            for target in stmt.targets:
                self._bind(target, t)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._bind(stmt.target, self.etaint(stmt.value))
            return
        if isinstance(stmt, ast.AugAssign):
            t = max(self.etaint(stmt.target), self.etaint(stmt.value))
            self._bind(stmt.target, t)
            return
        if isinstance(stmt, ast.If):
            if self.etaint(stmt.test) == TRACER:
                self.events.append(Event("if", stmt, self.fn))
            for s in stmt.body + stmt.orelse:
                self._visit(s)
            return
        if isinstance(stmt, ast.While):
            if self.etaint(stmt.test) == TRACER:
                self.events.append(Event("while", stmt, self.fn))
            for s in stmt.body + stmt.orelse:
                self._visit(s)
            return
        if isinstance(stmt, ast.Assert):
            if self.etaint(stmt.test) == TRACER:
                self.events.append(Event("assert", stmt, self.fn))
            return
        if isinstance(stmt, ast.For):
            it = stmt.iter
            if (
                isinstance(it, ast.Call)
                and isinstance(it.func, ast.Name)
                and it.func.id == "range"
                and any(self.etaint(a) == TRACER for a in it.args)
            ):
                self.events.append(Event("range_loop", stmt, self.fn))
            self._bind(stmt.target, self.etaint(it))
            for s in stmt.body + stmt.orelse:
                self._visit(s)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                if item.optional_vars is not None:
                    self._bind(
                        item.optional_vars, self.etaint(item.context_expr)
                    )
            for s in stmt.body:
                self._visit(s)
            return
        if isinstance(stmt, ast.Try):
            for s in stmt.body + stmt.orelse + stmt.finalbody:
                self._visit(s)
            for handler in stmt.handlers:
                for s in handler.body:
                    self._visit(s)
            return
        if isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self.etaint(stmt.value)
            return
        # Raise / Pass / Import / Global / Nonlocal / Delete: nothing to do.


def analyze_traced(
    info: ModuleInfo, array_fields: Set[str]
) -> Iterator[Tuple[TracedFn, List[Event]]]:
    """Run the taint engine over every traced function in the module."""
    for tf in traced_functions(info):
        engine = FunctionTaint(info, tf.fn, tf.kind, array_fields)
        yield tf, engine.run()


def enclosing_functions(
    tree: ast.Module,
) -> Dict[int, List[ast.FunctionDef]]:
    """Map ``id(node)`` -> chain of enclosing FunctionDefs (innermost
    first) for every node — lexical scope lookup for RPL102."""
    out: Dict[int, List[ast.FunctionDef]] = {}

    def walk(node: ast.AST, chain: List[ast.FunctionDef]) -> None:
        for child in ast.iter_child_nodes(node):
            nxt = chain
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nxt = [child, *chain]
            out[id(child)] = nxt
            walk(child, nxt)

    out[id(tree)] = []
    walk(tree, [])
    return out
