"""Paper Fig. 7 — sensitivity to the hyperparameter mu.

The paper sweeps mu for MobileNetV2 at 0.1% sparsity (mu=0 == Top-k) and
finds RegTop-k "rather stable against changes in mu". We sweep mu in the
low-dimensional linreg setting where RegTop-k's convergence reproduces
(App. B regime) and report the optimality gap per mu — the same stability
statement, with mu=0 == Top-k as in the paper's plot.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J, S = 2, 4, 0.5


def _gap(mu, seed=0, steps=8000):
    data = make_linreg(seed, N, J, 20, sigma2=1.0)
    kind = "topk" if mu == 0 else "regtopk"
    cfg = SparsifierConfig(kind=kind, sparsity=S, mu=max(mu, 1e-9))
    sim = DistributedSim(linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2)
    _, tr = sim.run(
        jnp.zeros(J), steps,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    return float(np.asarray(tr)[-1])


def run():
    rows = []
    mus = (0.0, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0)
    per_seed = {seed: {mu: _gap(mu, seed) for mu in mus} for seed in (0, 1)}
    for mu in mus:
        mean = np.mean([per_seed[s_][mu] for s_ in per_seed])
        rows.append(row(f"fig7/mu={mu:g}", 0.0, f"mean_gap@8000={mean:.3e}"))
    # the paper's protocol: mu is grid-searched per setting (Sec. 5.3);
    # claim = tuned RegTop-k beats Top-k (mu=0) on each instance
    wins = 0
    for s_, gaps in per_seed.items():
        tuned = min(g for mu, g in gaps.items() if mu > 0)
        rows.append(
            row(
                f"fig7/seed={s_}", 0.0,
                f"topk={gaps[0.0]:.3e};tuned_regtopk={tuned:.3e}",
            )
        )
        wins += tuned < gaps[0.0]
    rows.append(row("fig7/claim", 0.0, f"tuned_regtopk_beats_topk={wins}/2"))
    return rows
