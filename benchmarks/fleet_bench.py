"""Fleet-scale client sampling fronts — gap vs S, worker vs coordinate
weighting (ISSUE 9).

S-of-N client sampling (``Participation(kind="sampled")``) picks S
workers per round via a common-knowledge PRNG. Under the historical
*worker* weighting each sampled worker carries mass 1/S, so a coordinate
only k/J of the sampled masks selected is averaged against mass that
never arrived — the sparser the masks and the smaller S, the more the
aggregate is biased toward zero. *Coordinate* weighting
(``weighting="coordinate"``) renormalizes each coordinate by the mass of
the workers that actually sent it, which removes that shrinkage and
feeds RegTop-k's posterior the weight the server really used.

This bench draws the gap-vs-S front on the Fig-3 linear regression for
both weightings: rows ``fleet/<weighting>/S=...`` carry ``gap@STEPS``
in ``derived`` (accounting rows, us = 0), and the bench asserts
coordinate weighting strictly reduces the final gap whenever
S/N <= 0.25. The asserted front runs the *homogeneous* variant, which
isolates the shrinkage bias: with shared minimizers the 1/S damping
only slows convergence, so removing it is a pure win. Heterogeneous
rows (``fleet/het/...``) ride along unasserted — there client drift
adds a noise term that worker-mode shrinkage incidentally damps, and
which weighting wins depends on where the run sits on the
speed-vs-noise-floor trade. ``fleet/step`` times one jitted sampled
round at N = 2000, S = 32 — the gather/scatter simulator path whose
per-round work is O(S·J), not O(N·J).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J = 16, 200
STEPS = 200
SPARSITY = 0.05
SAMPLED_S = (2, 4, 8)  # S/N = 0.125, 0.25, 0.5
FLEET_N, FLEET_S, FLEET_J = 2000, 32, 200


def _gap(n_workers, s, weighting, steps=STEPS, dim=J, seed=3,
         homogeneous=True):
    data = make_linreg(seed, n_workers, dim, 400, sigma2=2.0,
                       homogeneous=homogeneous)
    sim = DistributedSim(
        linreg_grad_fn(data), n_workers, dim,
        SparsifierConfig(kind="regtopk", sparsity=SPARSITY, mu=16.0),
        learning_rate=1e-2,
        collective="sparse_allgather", codec="coo_fp32",
        participation=comm.Participation(kind="sampled", n_sampled=s,
                                         seed=7),
        weighting=weighting,
    )
    _, tr = sim.run(
        jnp.zeros(dim), steps,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    return float(np.asarray(tr)[-1])


def run():
    rows = []
    gaps = {}
    for s in SAMPLED_S:
        for weighting in ("worker", "coordinate"):
            g = gaps[(weighting, s)] = _gap(N, s, weighting)
            rows.append(row(
                f"fleet/{weighting}/S={s}", 0.0,
                f"gap@{STEPS}={g:.3e} N={N}",
            ))
    for weighting in ("worker", "coordinate"):
        g = _gap(N, 4, weighting, homogeneous=False)
        rows.append(row(
            f"fleet/het/{weighting}/S=4", 0.0,
            f"gap@{STEPS}={g:.3e} N={N}",
        ))
        assert np.isfinite(g)
    assert all(np.isfinite(g) for g in gaps.values()), gaps
    # the tentpole claim: per-coordinate renormalization strictly beats
    # the per-worker scalar whenever the round sees <= a quarter of the
    # fleet (sparse masks + small S is where the shrinkage bias bites)
    for s in SAMPLED_S:
        if s / N <= 0.25:
            assert gaps[("coordinate", s)] < gaps[("worker", s)], (
                s, gaps[("coordinate", s)], gaps[("worker", s)],
            )

    # timed row: one jitted sampled round at fleet scale — N = 2000
    # clients, S = 32 sampled, grads and sparsifier steps vmapped over
    # the 32 gathered states only
    data = make_linreg(5, FLEET_N, FLEET_J, 50, sigma2=2.0,
                       homogeneous=False)
    sim = DistributedSim(
        linreg_grad_fn(data), FLEET_N, FLEET_J,
        SparsifierConfig(kind="regtopk", sparsity=SPARSITY, mu=16.0),
        learning_rate=1e-2,
        collective="sparse_allgather", codec="coo_fp32",
        participation=comm.Participation(kind="sampled",
                                         n_sampled=FLEET_S, seed=11),
        weighting="coordinate",
    )
    step = jax.jit(lambda st: sim.step_fn(st)[0])
    state = step(sim.init(jnp.zeros(FLEET_J)))  # warm the cache
    us = time_call(step, state, iters=5)
    rows.append(row(
        "fleet/step", us, f"N={FLEET_N} S={FLEET_S} J={FLEET_J}",
    ))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run, "fleet_bench")
