"""Bucketed overlap scheduler bench (ISSUE 10 tentpole).

Three sections, each CI-gated:

* **Predicted schedule** — plans a heterogeneous leaf tree on a (2, 4) dp
  mesh with a slow-outer :class:`~repro.comm.cost.LinkTopo` (outer beta
  10x the intra link) and *asserts* the acceptance criteria in-bench: the
  4-bucket overlapped timeline is strictly below the synchronous per-leaf
  sum, and the 1-bucket timeline equals it (fp-tolerant). Accounting rows
  (``us=0``, skipped by the timing gate) publish the sync/overlapped
  microseconds and the speedup.
* **Measured replay** — times real per-bucket compute slices
  (``time_call`` on jitted backward-sized elementwise work) and replays
  them through the same :func:`~repro.comm.overlap.overlap_timeline`
  scheduler, confirming the overlapped round stays strictly below the
  measured-compute + modeled-wire synchronous sum.
* **Timed rounds** — runs the real ``make_sparsify_aggregate`` round
  (via ``assemble`` on a micro model) with ``overlap="off"`` vs
  ``overlap="buckets:3"`` and asserts the trained parameters are
  bit-for-bit identical — the off-switch guarantee — while reporting both
  step timings for the regression gate.

Standalone: ``python benchmarks/overlap_bench.py --json
BENCH_overlap.json`` feeds the CI perf gate (`tools/check_perf.py` vs
`benchmarks/baselines/BENCH_overlap.json`).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro import comm
from repro.comm.autotune import plan_tree

DP_SIZES = (2, 4)
# slow outer axis: 10x the intra link's per-byte cost (and 10x alpha) —
# the regime where hierarchical wins and its inter stage is worth hiding.
TOPO = comm.LinkTopo(
    (comm.AlphaBeta(1e-4, 1e-8), comm.AlphaBeta(1e-5, 1e-9))
)
N_BUCKETS = 4


def _leaf_tree():
    """A heterogeneous LeafPlan tree (embedding-sized shards down to tiny
    biases) — the shape real parameter trees have."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import LeafPlan

    sizes = [1 << 18, 1 << 17, 1 << 16, 1 << 16, 1 << 14, 1 << 10, 256, 64]
    return {
        f"leaf{i:02d}": LeafPlan(
            (n,), (n,), n, max(1, n // 32), P(None)
        )
        for i, n in enumerate(sizes)
    }


def _predicted_rows():
    tree = _leaf_tree()
    kw = dict(collectives=["hierarchical"])
    cp_sync = plan_tree(tree, DP_SIZES, TOPO, **kw)
    cp1 = plan_tree(
        tree, DP_SIZES, TOPO, overlap=comm.OverlapConfig(n_buckets=1), **kw
    )
    cpB = plan_tree(
        tree,
        DP_SIZES,
        TOPO,
        overlap=comm.OverlapConfig(n_buckets=N_BUCKETS),
        **kw,
    )
    # acceptance: strictly below synchronous at B buckets, equal at one.
    assert cpB.timeline.seconds < cp_sync.total_seconds, (
        f"overlapped {cpB.timeline.seconds:.6e}s is not strictly below "
        f"synchronous {cp_sync.total_seconds:.6e}s on a slow-outer topo"
    )
    assert np.isclose(
        cp1.timeline.seconds, cp1.total_seconds, rtol=1e-9
    ), (
        f"1-bucket timeline {cp1.timeline.seconds:.6e}s != synchronous "
        f"sum {cp1.total_seconds:.6e}s"
    )
    assert sorted(cpB.buckets.leaf_order()) == list(range(len(tree)))
    speedup = cp_sync.total_seconds / cpB.timeline.seconds
    return [
        row(
            "overlap/predicted/sync",
            0.0,
            f"seconds_us={cp_sync.total_seconds * 1e6:.1f};"
            f"leaves={len(tree)}",
        ),
        row(
            f"overlap/predicted/buckets={N_BUCKETS}",
            0.0,
            f"seconds_us={cpB.timeline.seconds * 1e6:.1f};"
            f"n_buckets={cpB.buckets.n_buckets};speedup={speedup:.3f}",
        ),
        row(
            "overlap/predicted/buckets=1",
            0.0,
            f"seconds_us={cp1.timeline.seconds * 1e6:.1f};"
            "equals_sync=1",
        ),
    ]


def _replay_rows():
    """Measure per-bucket compute, replay through the scheduler."""
    tree = _leaf_tree()
    cpB = plan_tree(
        tree,
        DP_SIZES,
        TOPO,
        collectives=["hierarchical"],
        overlap=comm.OverlapConfig(n_buckets=N_BUCKETS),
    )

    @jax.jit
    def slab(v):
        return jnp.tanh(v * 1e-3) + v * v

    # one backward-slice per bucket, sized by the bucket's leaf bytes —
    # real measured seconds threaded into the same timeline recurrence.
    comp = []
    for b in cpB.buckets.buckets:
        n = max(1024, int(math.sqrt(b.bytes_on_wire)) * 16)
        comp.append(
            time_call(slab, jnp.ones((n,), jnp.float32), iters=3) / 1e6
        )
    tl = comm.overlap_timeline(cpB.buckets, comp)
    assert tl.seconds < tl.sync_seconds, (
        f"measured replay: overlapped {tl.seconds:.6e}s is not strictly "
        f"below synchronous {tl.sync_seconds:.6e}s"
    )
    return [
        row(
            "overlap/replay/measured_compute",
            0.0,
            f"sync_us={tl.sync_seconds * 1e6:.1f};"
            f"overlap_us={tl.seconds * 1e6:.1f};"
            f"speedup={tl.sync_seconds / tl.seconds:.3f}",
        )
    ]


def _timed_rows():
    """Real aggregation rounds, off vs bucketed — bit-for-bit + timing."""
    from repro.compat import make_mesh
    from repro.core.distributed import (
        DistConfig,
        assemble,
        init_sparsifier_state,
    )
    from repro.core.sparsify import SparsifierConfig
    from repro.data import TokenPipeline
    from repro.models import ModelConfig, get_family
    from repro.optim import OptConfig, make_optimizer

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=128, remat=False,
    )
    mod = get_family(cfg)
    pipe = TokenPipeline(cfg, global_batch=4, seq=16)

    def train(overlap, steps=3):
        dist = DistConfig(
            sparsifier=SparsifierConfig(
                kind="regtopk", sparsity=0.05, mu=1.0
            ),
            optimizer=OptConfig(kind="adam", learning_rate=3e-3),
            aggregation="sparse_allgather",
            dp_axes=("data",),
            overlap=overlap,
        )
        asm = assemble(mod, cfg, dist, mesh)
        params, _ = mod.init(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(dist.optimizer)
        opt_state = opt.init(params)
        sp_state, _ = init_sparsifier_state(
            asm.plan, 1, mesh, ("data",), jnp.float32
        )
        step = jax.jit(asm.train_step)
        with mesh:
            for t in range(steps):
                params, opt_state, sp_state, m = step(
                    params, opt_state, sp_state, pipe.batch_at(t)
                )
            us = time_call(
                step, params, opt_state, sp_state, pipe.batch_at(0),
                iters=3,
            )
        return params, us

    p_off, us_off = train("off")
    p_on, us_on = train("buckets:3")
    diff = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on))
    )
    assert diff == 0.0, (
        f"overlap='buckets:3' diverged from 'off' by {diff:.3e} — the "
        "off-switch must be bit-for-bit"
    )
    return [
        row("overlap/spa/off", us_off, "bitforbit=1"),
        row("overlap/spa/buckets=3", us_on, "bitforbit=1"),
    ]


def run():
    return _predicted_rows() + _replay_rows() + _timed_rows()


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run, "overlap_bench")
