"""``repro.comm`` sweep: codec x strategy x sparsity (ISSUE 1 tentpole).

For every wire codec and payload collective, runs the N-worker simulator on
a heterogeneous linear-regression problem and

* asserts numerics-equivalence against the ``dense_allreduce`` reference:
  at every round the codec-path aggregated gradient is compared against
  dense aggregation *from the identical worker state* (exact for lossless
  codecs; <= 1e-2 relative for ``coo_q8``, whose quantization residual is
  error-fed back through ``eps``), and
* reports predicted (codec bit accounting through the alpha–beta pattern)
  vs. measured (actual encoded buffer sizes) bytes-on-wire per round,
  asserting ``measured <= predicted * 1.05``.

Standalone: ``python benchmarks/comm_bench.py --json BENCH_comm.json``
feeds the CI perf gate (`tools/check_perf.py` vs
`benchmarks/baselines/BENCH_comm.json`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.core.selectors import sparsity_to_k
from repro.data.pipeline import linreg_grad_fn, make_linreg

N_WORKERS = 8
LENGTH = 256
STEPS = 25
SPARSITIES = (0.01, 0.05, 0.2)
STRATEGIES = ("sparse_allgather", "hierarchical")


def _roundwise_rel_err(grad_fn, S, cname, sname):
    """Max over rounds of ||agg_codec - agg_dense|| / ||agg_dense||, both
    aggregations computed from the *same* evolving codec-path state."""
    cfg = SparsifierConfig(kind="regtopk", sparsity=S, mu=1.0)

    def mk(**kw):
        return DistributedSim(
            grad_fn, N_WORKERS, LENGTH, cfg, learning_rate=1e-2, **kw
        )

    sim = mk(codec=cname, collective=sname)
    ref = mk()  # dense_allreduce
    step_c = jax.jit(sim.step_fn)
    step_d = jax.jit(ref.step_fn)
    state = sim.init(jnp.zeros(LENGTH))
    err = 0.0
    for _ in range(STEPS):
        new_state, g_c = step_c(state)
        _, g_d = step_d(state)
        denom = max(float(jnp.linalg.norm(g_d)), 1e-30)
        err = max(err, float(jnp.linalg.norm(g_c - g_d)) / denom)
        state = new_state
    return sim, err


def run():
    data = make_linreg(5, N_WORKERS, LENGTH, 200)
    grad_fn = linreg_grad_fn(data)
    rows = []
    for S in SPARSITIES:
        k = sparsity_to_k(LENGTH, S)
        for cname in sorted(comm.CODECS):
            codec = comm.get_codec(cname)
            payload_shape = jax.eval_shape(
                lambda v, i: codec.encode(v, i, LENGTH),
                jax.ShapeDtypeStruct((k,), jnp.float32),
                jax.ShapeDtypeStruct((k,), jnp.int32),
            )
            for sname in STRATEGIES:
                sim, rel = _roundwise_rel_err(grad_fn, S, cname, sname)
                tol = 1e-5 if codec.lossless else 1e-2
                assert rel <= tol, (
                    f"{cname}/{sname}/S={S}: rel err {rel:.2e} > {tol}"
                )
                pred = comm.predicted_bytes(
                    codec, sname, LENGTH, k, (N_WORKERS,)
                )
                meas = comm.measured_bytes(
                    sname, LENGTH, payload_shape, (N_WORKERS,)
                )
                assert meas <= pred * 1.05, (
                    f"{cname}/{sname}/S={S}: measured {meas} B > "
                    f"1.05 x predicted {pred} B"
                )
                est = sim.wire_bytes_per_round()
                us = time_call(
                    jax.jit(lambda s: sim.step_fn(s)[0]),
                    sim.init(jnp.zeros(LENGTH)),
                    iters=3,
                )
                rows.append(
                    row(
                        f"comm_bench/{cname}/{sname}/S={S}",
                        us,
                        f"predicted_B={pred};measured_B={meas};"
                        f"rel_err={rel:.2e};alphabeta_us="
                        f"{est.seconds * 1e6:.1f};msgs={est.n_messages}",
                    )
                )
        dense_pred = comm.predicted_bytes(
            "coo_fp32", "dense_allreduce", LENGTH, k, (N_WORKERS,)
        )
        rows.append(
            row(
                f"comm_bench/dense_allreduce/S={S}",
                0.0,
                f"predicted_B={dense_pred};measured_B={dense_pred};"
                "rel_err=0.0",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run, "comm_bench")
