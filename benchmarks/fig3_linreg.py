"""Paper Fig. 3 — distributed linear regression, optimality gap vs iters.

Setting: N=20 workers, J=100, Dn=500, full-batch GD, eta=1e-2, data per
Sec. 5.1 (U=0, sigma^2=5, h^2=1, eps^2=0.5). Reported: optimality gap
||theta_t - theta*|| at S in {0.4, 0.6, 0.9} for top-k / regtop-k / none,
plus our beyond-paper coordinated variants (coordtopk, cyclic).

Reproduction status (EXPERIMENTS.md §Claims): Top-k's plateau reproduces
exactly. Literal Alg. 2 RegTop-k reproduces the low-dim convergence
(tab2_lowdim) and the toy (fig1) but in THIS instance plateaus with
Top-k for every mu we searched; the coordinated variants derived from the
paper's own analysis converge to machine precision at every S.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J = 20, 100


def _run(kind, S, mu=16.0, steps=2500, seed=42, homogeneous=False):
    data = make_linreg(seed, N, J, 500, homogeneous=homogeneous)
    cfg = SparsifierConfig(kind=kind, sparsity=S, mu=mu)
    sim = DistributedSim(
        linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2
    )
    fin, tr = sim.run(
        jnp.zeros(J),
        steps,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    return np.asarray(tr)


def run():
    rows = []
    for S in (0.4, 0.6, 0.9):
        for kind in ("topk", "regtopk", "dgc", "coordtopk", "none"):
            tr = _run(kind, S)
            us = time_call(lambda k=kind, s=S: _run(k, s, steps=250), iters=1)
            rows.append(
                row(
                    f"fig3_linreg/S={S}/{kind}",
                    us / 250,
                    f"gap@1000={tr[999]:.3e};gap@2500={tr[-1]:.3e}",
                )
            )
    return rows
