"""Autotune sweep: per-leaf (codec x collective) planning vs fixed choices.

For a grid of leaves (tiny bias .. dense-ish embedding shard) and dp meshes
(single-axis and multi-pod), asserts the ISSUE-2 acceptance criteria:

* the auto plan's predicted bytes are <= the best single *fixed* codec's
  (each fixed codec planned with the same collective-selection procedure),
* measured bytes (actual encoded buffer sizes) <= 1.05 x predicted, and
* round-wise aggregation under ``codec="auto"`` stays numerically
  equivalent to ``dense_allreduce`` (auto never admits lossy codecs).

Also runs the :mod:`repro.comm.calibrate` micro-harness: times real
collectives on the host backend (forced to 8 CPU devices when launched
directly), fits alpha/beta — uniform *and* per-axis (``calibrate_topo`` on
a (2, 4) dp mesh) — and reports the fitted models plus the plans they
induce.

The ``topo/`` section asserts the ISSUE-3 tentpole: under a per-axis
:class:`~repro.comm.cost.LinkTopo` with a >=10x slower outer axis the
planner flips large moderately-sparse leaves to ``hierarchical`` (which a
uniform model provably never strictly prefers), while a uniform LinkTopo
reproduces the scalar AlphaBeta predictions bit-for-bit.
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:  # force a multi-device host for calibration
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

# (label, local_len, sparsity) — shapes spanning the codec trade-off space
LEAVES = (
    ("bias_tiny", 64, 0.05),
    ("norm_small", 1024, 0.01),
    ("mlp_shard", 16384, 0.01),
    ("embed_dense", 65536, 0.125),  # S > 1/32: bitmap territory
    ("embed_sparse", 262144, 0.001),
)
MESHES = ((8,), (16,), (2, 8), (4, 8))
FIXED_CODECS = tuple(
    n for n in sorted(comm.CODECS) if comm.get_codec(n).lossless
)


def _sweep_rows():
    from repro.core.selectors import sparsity_to_k

    rows = []
    for label, L, S in LEAVES:
        k = sparsity_to_k(L, S)
        for dp in MESHES:
            auto = comm.choose_leaf(L, k, dp)
            # best single fixed codec: same planning procedure, codec pinned
            fixed = {
                c: comm.choose_leaf(L, k, dp, codecs=[c])
                for c in FIXED_CODECS
            }
            best_fixed_bytes = min(
                d.cost.bytes_on_wire for d in fixed.values()
            )
            assert auto.cost.bytes_on_wire <= best_fixed_bytes, (
                f"{label}/dp={dp}: auto {auto.codec}/{auto.collective} "
                f"predicts {auto.cost.bytes_on_wire} B > best fixed "
                f"{best_fixed_bytes} B"
            )
            assert auto.cost.seconds <= min(
                d.cost.seconds for d in fixed.values()
            ) * (1 + 1e-12), f"{label}/dp={dp}: auto not seconds-optimal"
            # measured bytes of the chosen pair vs its own prediction
            codec = comm.get_codec(auto.codec)
            payload_shape = jax.eval_shape(
                lambda v, i: codec.encode(v, i, L),
                jax.ShapeDtypeStruct((k,), jnp.float32),
                jax.ShapeDtypeStruct((k,), jnp.int32),
            )
            meas = comm.measured_bytes(
                auto.collective, L, payload_shape, dp
            )
            assert meas <= auto.cost.bytes_on_wire * 1.05, (
                f"{label}/dp={dp}: measured {meas} B > 1.05 x predicted "
                f"{auto.cost.bytes_on_wire} B"
            )
            saved = best_fixed_bytes - auto.cost.bytes_on_wire
            rows.append(
                row(
                    f"autotune/{label}/dp={'x'.join(map(str, dp))}",
                    auto.cost.seconds * 1e6,
                    f"pick={auto.codec}/{auto.collective};"
                    f"predicted_B={auto.cost.bytes_on_wire};"
                    f"measured_B={meas};saved_vs_best_fixed_B={saved}",
                )
            )
    return rows


def _tree_rows():
    """Whole-tree totals: per-leaf auto vs the best single global codec.

    This is where heterogeneity pays — one codec cannot be right for both
    the dense-ish embedding shard and the tiny bias."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import LeafPlan
    from repro.core.selectors import sparsity_to_k

    rows = []
    for dp in ((8,), (4, 8)):
        tree = {
            label: LeafPlan((L,), (L,), L, sparsity_to_k(L, S), P(None))
            for label, L, S in LEAVES
        }
        auto_plan = comm.plan_tree(tree, dp)
        fixed_totals = {
            c: comm.plan_tree(tree, dp, codecs=[c]).total_bytes
            for c in FIXED_CODECS
        }
        best_c = min(fixed_totals, key=fixed_totals.get)
        assert auto_plan.total_bytes <= fixed_totals[best_c], (
            f"dp={dp}: auto tree total {auto_plan.total_bytes} B > best "
            f"single codec {best_c} ({fixed_totals[best_c]} B)"
        )
        picks = {
            label: f"{d.codec}/{d.collective}"
            for label, d in auto_plan.decisions.items()
        }
        rows.append(
            row(
                f"autotune/tree/dp={'x'.join(map(str, dp))}",
                auto_plan.total_seconds * 1e6,
                f"auto_B={auto_plan.total_bytes};"
                f"best_single_codec={best_c}:{fixed_totals[best_c]}B;"
                f"saved_B={fixed_totals[best_c] - auto_plan.total_bytes};"
                + ";".join(f"{k}={v}" for k, v in sorted(picks.items())),
            )
        )
    return rows


def _equivalence_rows():
    """codec='auto' training matches dense_allreduce round-wise."""
    N, L, steps = 8, 256, 25
    data = make_linreg(5, N, L, 200)
    grad_fn = linreg_grad_fn(data)
    rows = []
    for S in (0.01, 0.07, 0.2):
        cfg = SparsifierConfig(kind="regtopk", sparsity=S, mu=1.0)
        sim = DistributedSim(
            grad_fn, N, L, cfg, learning_rate=1e-2,
            codec="auto", collective="auto",
        )
        assert sim.codec in FIXED_CODECS, (
            f"auto resolved to lossy/unknown codec {sim.codec}"
        )
        ref = DistributedSim(grad_fn, N, L, cfg, learning_rate=1e-2)
        step_a = jax.jit(sim.step_fn)
        step_d = jax.jit(ref.step_fn)
        state = sim.init(jnp.zeros(L))
        err = 0.0
        for _ in range(steps):
            new_state, g_a = step_a(state)
            _, g_d = step_d(state)
            denom = max(float(jnp.linalg.norm(g_d)), 1e-30)
            err = max(err, float(jnp.linalg.norm(g_a - g_d)) / denom)
            state = new_state
        assert err <= 1e-5, (
            f"auto S={S} ({sim.codec}/{sim.resolved_collective}) diverged "
            f"from dense_allreduce: rel err {err:.2e}"
        )
        rows.append(
            row(
                f"autotune/equiv/S={S}",
                0.0,
                f"pick={sim.codec}/{sim.resolved_collective};"
                f"rel_err={err:.2e}",
            )
        )
    return rows


def _topo_rows():
    """Per-link-class planning: uniform parity + the hierarchical flip."""
    from repro.core.selectors import sparsity_to_k

    rows = []
    # 1) a uniform LinkTopo is bit-for-bit the scalar AlphaBeta model
    scalar = comm.AlphaBeta(alpha=2e-5, beta=3e-11)
    for label, L, S in LEAVES:
        k = sparsity_to_k(L, S)
        for dp in MESHES:
            topo = comm.LinkTopo.uniform(scalar, len(dp))
            for c in FIXED_CODECS:
                for s in sorted(comm.COLLECTIVES):
                    u = comm.predict(c, s, L, k, dp, scalar)
                    t = comm.predict(c, s, L, k, dp, topo)
                    assert u == t, (
                        f"uniform-topo parity broke: {c}/{s} {label} dp={dp}"
                        f" {u} != {t}"
                    )
    # 2) slow outer axis (10x alpha and beta) flips big moderately-sparse
    # leaves to hierarchical. On *bytes* a uniform bandwidth-only model
    # (alpha=0) provably never strictly prefers it (docs/comm.md envelope
    # proof) — the per-axis beta is what unlocks the choice.
    inter_link = comm.AlphaBeta(alpha=1e-5, beta=1e-10)
    intra_link = comm.AlphaBeta(alpha=1e-6, beta=1e-11)
    for dp in ((2, 4), (4, 8)):
        topo = comm.LinkTopo(
            (inter_link,) * (len(dp) - 1) + (intra_link,)
        )
        L, S = 1_000_000, 0.1
        k = sparsity_to_k(L, S)
        het = comm.choose_leaf(L, k, dp, topo)
        uni = comm.choose_leaf(
            L, k, dp, comm.AlphaBeta(alpha=0.0, beta=intra_link.beta)
        )
        assert het.collective == "hierarchical", (
            f"dp={dp}: slow-outer topo picked {het.collective}, "
            "expected hierarchical"
        )
        assert uni.collective != "hierarchical", (
            f"dp={dp}: uniform bandwidth-only model picked hierarchical"
        )
        saved = comm.predict(
            het.codec, "sparse_allgather", L, k, dp, topo
        ).seconds - het.cost.seconds
        rows.append(
            row(
                f"autotune/topo/dp={'x'.join(map(str, dp))}",
                het.cost.seconds * 1e6,
                f"pick={het.codec}/{het.collective};"
                f"uniform_pick={uni.codec}/{uni.collective};"
                f"saved_vs_allgather_us={saved * 1e6:.1f}",
            )
        )
    return rows


def _calibration_rows():
    res = comm.run_calibration(iters=3)
    if not res.calibrated:
        return [
            row("autotune/calibrate", 0.0, "skipped=single_device")
        ]
    m = res.model
    # the fitted model must still induce a valid plan on every sweep point
    from repro.core.selectors import sparsity_to_k

    for label, L, S in LEAVES:
        d = comm.choose_leaf(L, sparsity_to_k(L, S), (8,), m)
        assert d.codec in comm.CODECS and d.collective in comm.COLLECTIVES
    return [
        row(
            "autotune/calibrate",
            res.residual * 1e6,
            f"alpha_s={m.alpha:.3e};beta_s_per_B={m.beta:.3e};"
            f"samples={len(res.samples)}",
        )
    ]


def _topo_calibration_rows():
    """Per-axis calibration on a (2, 4) host mesh: fit one AlphaBeta per dp
    axis, check the topo it assembles still plans every sweep point."""
    import numpy as np

    from repro.compat import make_mesh
    from repro.core.selectors import sparsity_to_k

    if len(jax.devices()) < 8:
        return [row("autotune/calibrate_topo", 0.0, "skipped=few_devices")]
    mesh = make_mesh((2, 4), ("pod", "data"))
    res = comm.calibrate_topo(mesh=mesh, dp_axes=("pod", "data"), iters=3)
    assert res.calibrated and res.topo.n_axes == 2
    for label, L, S in LEAVES:
        d = comm.choose_leaf(L, sparsity_to_k(L, S), (2, 4), res.topo)
        assert d.codec in comm.CODECS and d.collective in comm.COLLECTIVES
    per = ";".join(
        f"{ax}:alpha={c.model.alpha:.3e},beta={c.model.beta:.3e}"
        for ax, c in zip(res.axes, res.per_axis)
    )
    rms = float(np.mean([c.residual for c in res.per_axis]))
    return [row("autotune/calibrate_topo", rms * 1e6, per)]


def run():
    return (
        _sweep_rows()
        + _tree_rows()
        + _topo_rows()
        + _equivalence_rows()
        + _calibration_rows()
        + _topo_calibration_rows()
    )


if __name__ == "__main__":
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    emit(run())
