"""Serving microbenchmark — decode tokens/s per family (smoke configs, CPU).

Exercises the exact serve_step the decode_32k / long_500k dry-run shapes
lower (KV ring buffers, SSM state carry, MoE dropless decode), end to end
through jit. Absolute numbers are CPU-host; the derived column carries the
per-token cache/state bytes that bound TPU decode.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro import configs as cfglib
from repro.models import get_family

ARCHS = ["qwen2.5-3b", "mamba2-780m", "zamba2-7b", "mixtral-8x7b",
         "granite-3-8b-swa"]
BATCH, TOKENS, MAXLEN = 4, 16, 64


def _bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def run():
    rows = []
    for arch in ARCHS:
        cfg = cfglib.get_config(arch).smoke_variant()
        mod = get_family(cfg)
        params, _ = mod.init(jax.random.PRNGKey(0), cfg)
        cache = mod.init_cache(cfg, BATCH, MAXLEN)
        step = jax.jit(lambda p, c, t: mod.decode_step(p, cfg, c, t))
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        logits, cache = step(params, cache, tok)  # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(TOKENS):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        rows.append(
            row(
                f"serve/{arch}",
                1e6 * dt / TOKENS,
                f"tok_s={BATCH * TOKENS / dt:.1f};"
                f"cache_bytes={_bytes(cache)};smoke",
            )
        )
    return rows
