"""Paper Fig. 4 — homogeneous vs heterogeneous data (S=0.6).

Claim: with strictly homogeneous data (identical t_n, eps=0) both Top-k
and RegTop-k track unsparsified GD; with heterogeneity Top-k oscillates at
a fixed distance.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J = 20, 100


def _run(kind, homogeneous, steps=2500, mu=16.0):
    data = make_linreg(7, N, J, 500, sigma2=2.0, homogeneous=homogeneous)
    cfg = SparsifierConfig(kind=kind, sparsity=0.6, mu=mu)
    sim = DistributedSim(linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2)
    fin, tr = sim.run(
        jnp.zeros(J), steps,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    return np.asarray(tr)


def run():
    rows = []
    for homo in (True, False):
        tag = "homo" if homo else "hetero"
        gaps = {k: _run(k, homo) for k in ("topk", "regtopk", "coordtopk", "none")}
        for k, tr in gaps.items():
            rows.append(
                row(f"fig4/{tag}/{k}", 0.0, f"gap@2500={tr[-1]:.3e}")
            )
        if homo:
            ok = gaps["topk"][-1] < 10 * max(gaps["none"][-1], 1e-7)
            rows.append(row("fig4/claim_homo_tracks", 0.0, f"topk_tracks_none={ok}"))
        else:
            ok = gaps["topk"][-1] > 100 * gaps["none"][-1]
            rows.append(row("fig4/claim_hetero_gap", 0.0, f"topk_stuck={ok}"))
    return rows
