"""Paper Fig. 1 — toy 2-worker logistic regression (J=2, eta=0.9).

Claim: Top-1 makes no progress for ~50 iterations (largest entries cancel
at the server); RegTop-1 tracks centralized (unsparsified) training.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import DistributedSim, SparsifierConfig

X = jnp.array([[100.0, 1.0], [-100.0, 1.0]])


def _grad_fn(theta, n):
    xn = X[n]
    e = jnp.exp(-jnp.dot(theta, xn))
    return -e * xn / (1 + e)


def _loss(theta):
    return jnp.mean(jnp.log(1 + jnp.exp(-X @ theta)))


def _run(kind, steps=100):
    cfg = SparsifierConfig(kind=kind, sparsity=0.5, mu=1.0)
    sim = DistributedSim(
        _grad_fn, n_workers=2, length=2, sparsifier_cfg=cfg, learning_rate=0.9
    )
    fin, trace = sim.run(jnp.array([0.0, 1.0]), steps, trace_fn=_loss)
    return np.asarray(trace)


def run():
    rows = []
    traces = {}
    for kind in ("topk", "regtopk", "none"):
        us = time_call(lambda k=kind: _run(k), iters=3)
        traces[kind] = _run(kind)
        t = traces[kind]
        rows.append(
            row(
                f"fig1_toy/{kind}",
                us / 100,
                f"loss@50={t[49]:.4f};loss@99={t[-1]:.4f}",
            )
        )
    stuck = abs(traces["topk"][49] - traces["topk"][0]) < 1e-6
    tracks = abs(traces["regtopk"][49] - traces["none"][49]) < 0.01
    rows.append(
        row(
            "fig1_toy/claim",
            0.0,
            f"top1_stuck_50it={stuck};regtop1_tracks_ideal={tracks}",
        )
    )
    return rows
