"""§Perf summary — hillclimb before/after + multi-pod scaling, from artifacts."""
from __future__ import annotations

from benchmarks.common import row
from benchmarks.roofline import load
from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16

HILLCLIMBS = {
    ("deepseek-moe-16b", "train_4k"): [
        ("baseline", ""),
        ("H1.1 gather dispatch", "moe_gather"),
        ("H1.2 group 512", "moe_g512"),
        ("H1.3 +expert parallel", "moe_ep_g512"),
        ("H1.4 +dots remat", "moe_ep_g512_dots"),
    ],
    ("qwen2.5-3b", "train_4k"): [
        ("baseline", ""),
        ("H2.1 dots remat", "remat_dots"),
    ],
    ("phi3-medium-14b", "train_4k"): [
        ("baseline", ""),
        ("H3.1 pad heads 48", "pad_heads48"),
        ("H3.2 +dots remat", "pad_heads48_dots"),
    ],
    ("mixtral-8x7b", "prefill_32k"): [
        ("baseline", ""),
        ("H4.1 group 512", "moe_g512"),
    ],
    ("zamba2-7b", "train_4k"): [
        ("baseline", ""),
        ("H5.1 dots remat", "remat_dots"),
    ],
    ("whisper-tiny", "train_4k"): [
        ("baseline", ""),
        ("transfer: pad heads 16", "pad_heads16"),
    ],
    ("internvl2-1b", "train_4k"): [
        ("baseline", ""),
        ("transfer: pad heads 16", "pad_heads16"),
    ],
}


def run():
    rows = []
    recs = load()
    for (arch, shape), steps in HILLCLIMBS.items():
        for label, tag in steps:
            r = recs.get((arch, shape, "16x16", tag))
            if r is None:
                continue
            rows.append(
                row(
                    f"perf/{arch}/{label}",
                    0.0,
                    (
                        f"compute={r['flops'] / PEAK_FLOPS_BF16:.3e}s;"
                        f"collective={r['collective_bytes']['total'] / ICI_BW:.3e}s;"
                        f"peakGiB={r['mem']['peak_bytes'] / 2**30:.2f}"
                    ),
                )
            )
    # multi-pod scaling: collective growth when the pod axis joins dp
    for arch in ("qwen2.5-3b", "mixtral-8x7b", "mamba2-780m"):
        a = recs.get((arch, "train_4k", "16x16", ""))
        b = recs.get((arch, "train_4k", "2x16x16", ""))
        if a and b:
            rows.append(
                row(
                    f"perf/multipod/{arch}",
                    0.0,
                    (
                        f"coll_1pod={a['collective_bytes']['total']:.3e}B;"
                        f"coll_2pod={b['collective_bytes']['total']:.3e}B;"
                        "ratio={:.2f}".format(
                            b["collective_bytes"]["total"]
                            / a["collective_bytes"]["total"]
                        )
                    ),
                )
            )
    return rows
