"""Kernel microbenchmarks (interpret-mode on CPU; layout-identical to TPU).

us_per_call is CPU interpret-mode time (NOT TPU perf); the derived column
reports the analytic HBM-traffic model that determines TPU time:
fused regtopk_score moves 5 J-sized streams vs ~9 unfused.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.kernels import ops, ref

N = 1 << 18  # 256k elements


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    a, a_prev, g_prev = (3.0 * jax.random.normal(k, (N,)) for k in ks[:3])
    s_prev = (jax.random.uniform(ks[3], (N,)) > 0.5).astype(jnp.float32)
    rows = []

    fused = lambda x: ops.regtopk_score(
        x, a_prev, s_prev, g_prev, omega=0.05, mu=1.0, interpret=True
    )
    unfused = jax.jit(
        lambda x: ref.regtopk_score_ref(x, a_prev, s_prev, g_prev, omega=0.05, mu=1.0)
    )
    rows.append(row("kernel/regtopk_score_fused", time_call(fused, a, iters=3),
                    f"J={N};streams=5x4B;tpu_time_est={5*4*N/819e9*1e6:.1f}us"))
    rows.append(row("kernel/regtopk_score_ref", time_call(unfused, a, iters=3),
                    f"J={N};streams~9x4B"))

    score = jnp.abs(a)
    k = max(1, int(0.001 * N))
    thr = lambda s: ops.threshold_topk_mask(s, k, interpret=True)
    exact = jax.jit(lambda s: jax.lax.top_k(s, k))
    rows.append(row("kernel/threshold_topk", time_call(thr, score, iters=3),
                    f"k={k};passes=25;tpu_time_est={25*4*N/819e9*1e6:.1f}us"))
    rows.append(row("kernel/exact_topk_xla", time_call(exact, score, iters=3),
                    f"k={k};sort_bound"))

    hier = lambda s: ops.hierarchical_topk(s, k, m=16, interpret=True)
    rows.append(row("kernel/hierarchical_topk", time_call(hier, score, iters=3),
                    f"k={k};candidates={N // 8192 * 16}"))
    return rows
