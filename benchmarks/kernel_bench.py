"""Kernel microbenchmarks (interpret-mode on CPU; layout-identical to TPU).

us_per_call is CPU interpret-mode time (NOT TPU perf); the derived column
reports the analytic HBM-traffic model that determines TPU time:
fused regtopk_score moves 5 J-sized streams vs ~9 unfused, and the fused
select→encode pipeline (ISSUE 5 tentpole) moves 4 — the score never
leaves registers, so the dense score write-back, the selector re-read and
the payload gather all disappear. The ``hbm_fused_B``/``hbm_unfused_B``
columns are asserted strictly ordered here (the acceptance criterion) and
shared with the ``fastpath="auto"`` throughput table
(`src/repro/comm/fastpath.py`).

Standalone: ``python benchmarks/kernel_bench.py --json BENCH_kernels.json``
feeds the CI perf gate (`tools/check_perf.py` vs
`benchmarks/baselines/BENCH_kernels.json`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.comm import fastpath
from repro.kernels import ops, ref

N = 1 << 18  # 256k elements


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    a, a_prev, g_prev = (3.0 * jax.random.normal(k, (N,)) for k in ks[:3])
    s_prev = (jax.random.uniform(ks[3], (N,)) > 0.5).astype(jnp.float32)
    rows = []

    fused = lambda x: ops.regtopk_score(
        x, a_prev, s_prev, g_prev, omega=0.05, mu=1.0, interpret=True
    )
    unfused = jax.jit(
        lambda x: ref.regtopk_score_ref(x, a_prev, s_prev, g_prev, omega=0.05, mu=1.0)
    )
    rows.append(row("kernel/regtopk_score_fused", time_call(fused, a, iters=3),
                    f"J={N};streams=5x4B;tpu_time_est={5*4*N/819e9*1e6:.1f}us"))
    rows.append(row("kernel/regtopk_score_ref", time_call(unfused, a, iters=3),
                    f"J={N};streams~9x4B"))

    score = jnp.abs(a)
    k = max(1, int(0.001 * N))
    thr = lambda s: ops.threshold_topk_mask(s, k, interpret=True)
    exact = jax.jit(lambda s: jax.lax.top_k(s, k))
    rows.append(row("kernel/threshold_topk", time_call(thr, score, iters=3),
                    f"k={k};passes=25;tpu_time_est={25*4*N/819e9*1e6:.1f}us"))
    rows.append(row("kernel/exact_topk_xla", time_call(exact, score, iters=3),
                    f"k={k};sort_bound"))

    hier = lambda s: ops.hierarchical_topk(s, k, m=16, interpret=True)
    rows.append(row("kernel/hierarchical_topk", time_call(hier, score, iters=3),
                    f"k={k};candidates={N // 8192 * 16}"))

    # --- fused select→encode pipeline (ISSUE 5 tentpole) -----------------
    # one pass: score in registers → per-tile candidates → compact payload.
    # The analytic HBM column is the acceptance criterion: the fused
    # pipeline's traffic must sit strictly below the unfused sum
    # (score write-back + selector re-read + gather).
    m = fastpath.candidate_budget(N, k)
    fused_se = lambda x: ops.fused_select_encode(
        x, a_prev, s_prev, g_prev, k=k, omega=0.05, mu=1.0, m=m,
        interpret=True,
    )
    unfused_se = jax.jit(
        lambda x: ref.fused_select_encode_ref(
            x, a_prev, s_prev, g_prev, k, omega=0.05, mu=1.0
        )
    )
    hbm_f = fastpath.fused_hbm_bytes(N, k, m)
    hbm_u = fastpath.unfused_hbm_bytes(N, k)
    assert hbm_f < hbm_u, (
        f"fused pipeline HBM traffic {hbm_f} B must sit strictly below "
        f"the unfused select→encode sum {hbm_u} B"
    )
    vals, idx, ok = fused_se(a)
    assert bool(ok), "fused certificate should hold on Gaussian scores"
    rows.append(row(
        "kernel/fused_select_encode",
        time_call(fused_se, a, iters=3),
        f"k={k};m={m};hbm_fused_B={hbm_f};hbm_unfused_B={hbm_u};"
        f"tpu_time_est={hbm_f / 819e9 * 1e6:.1f}us",
    ))
    rows.append(row(
        "kernel/unfused_select_encode_ref",
        time_call(unfused_se, a, iters=3),
        f"k={k};hbm_B={hbm_u};"
        f"tpu_time_est={hbm_u / 819e9 * 1e6:.1f}us",
    ))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run, "kernel_bench")
