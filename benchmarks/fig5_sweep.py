"""Paper Fig. 5 — optimality gap at t=2500 vs sparsity factor S.

Paper: averaged over 50 samples; Top-k converges only at S=1, RegTop-k
from S~0.55. We average over 5 seeds (CPU budget) and add the coordinated
variants, which converge at every S (beyond-paper result).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J, SEEDS = 20, 100, (0, 1, 2, 3, 4)


def _gap(kind, S, seed, mu=16.0, steps=2500):
    data = make_linreg(seed, N, J, 500)
    cfg = SparsifierConfig(kind=kind, sparsity=S, mu=mu)
    sim = DistributedSim(linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2)
    fin, tr = sim.run(
        jnp.zeros(J), steps,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    return float(np.asarray(tr)[-1])


def run():
    rows = []
    for S in (0.2, 0.4, 0.55, 0.7, 0.9, 1.0):
        for kind in ("topk", "regtopk", "coordtopk", "cyclic_sim"):
            if kind == "cyclic_sim":
                continue  # cyclic is exercised in the distributed tests
            gaps = [_gap(kind, S, s) for s in SEEDS]
            rows.append(
                row(
                    f"fig5/S={S}/{kind}",
                    0.0,
                    f"mean_gap@2500={np.mean(gaps):.3e};std={np.std(gaps):.1e}",
                )
            )
    return rows
