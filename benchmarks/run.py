"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  fig1_toy      — paper Fig. 1 (toy logistic; exact repro)
  fig3_linreg   — paper Fig. 3 (linreg gap vs iters)
  fig4_hetero   — paper Fig. 4 (homogeneous vs heterogeneous)
  fig5_sweep    — paper Fig. 5 (gap vs sparsity, seed-averaged)
  tab2_lowdim   — paper App. B (low-dim tracking + mask overlap)
  fig6_nn_proxy — paper Fig. 6/Tab. 1 (NN training proxy)
  fig7_mu_sweep — paper Fig. 7 (mu sensitivity; mu=0 == Top-k)
  comm_volume   — Sec. 2.2 compression table
  comm_bench    — repro.comm codec x strategy x sparsity sweep (ISSUE 1)
  autotune_bench— per-leaf (codec x collective) planner + calibration (ISSUE 2)
  straggler_bench — convergence gap vs dropout x sparsity, partial-round
                  cost asserts (ISSUE 4)
  adaptive_bench — error-budget vs static-k fronts: bytes-on-wire vs
                  distance-to-optimum (ISSUE 8)
  fleet_bench   — S-of-N client-sampling fronts: worker vs coordinate
                  weighting + fleet-scale sampled round timing (ISSUE 9)
  overlap_bench — bucketed overlap scheduler: predicted + measured-replay
                  timelines vs synchronous, bit-for-bit off switch
                  (ISSUE 10)
  kernel_bench  — Pallas kernel microbenches
  serve_bench   — decode tokens/s per family + per-token cache bytes
  roofline      — §Roofline terms from the dry-run artifacts
  perf_summary  — §Perf hillclimb before/after + multi-pod scaling
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "fig1_toy",
    "tab2_lowdim",
    "fig3_linreg",
    "fig4_hetero",
    "fig5_sweep",
    "fig6_nn_proxy",
    "fig7_mu_sweep",
    "comm_volume",
    "comm_bench",
    "autotune_bench",
    "straggler_bench",
    "adaptive_bench",
    "fleet_bench",
    "overlap_bench",
    "kernel_bench",
    "serve_bench",
    "roofline",
    "perf_summary",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for r in mod.run():
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}",
                      flush=True)
        except Exception as e:
            failures += 1
            print(f"{name},nan,ERROR:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
