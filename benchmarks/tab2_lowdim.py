"""Paper Appendix B (Fig. 8 / Table 2) — low-dim N=2, J=4 tracking.

Claims: (a) Top-k never converges to the global optimum for S<1;
(b) RegTop-k converges for S in {0.5, 0.75} (k=2,3) at suitable mu;
(c) RegTop-k's masks coordinate across workers (B.3).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J = 2, 4


def _run(kind, S, mu, seed=0, steps=8000):
    data = make_linreg(seed, N, J, 20, sigma2=1.0)
    cfg = SparsifierConfig(kind=kind, sparsity=S, mu=mu)
    sim = DistributedSim(linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2)
    fin, tr = sim.run(
        jnp.zeros(J), steps,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    # mask overlap (B.3): fraction of coordinates where both workers agree
    masks = np.asarray(fin.worker_states.s_prev)
    overlap = float((masks[0] == masks[1]).mean())
    return float(np.asarray(tr)[-1]), overlap


def run():
    rows = []
    best = {}
    for S in (0.5, 0.75):
        for kind in ("topk", "regtopk"):
            cands = [1.0, 3.0, 10.0] if kind == "regtopk" else [1.0]
            gaps = [(mu,) + _run(kind, S, mu) for mu in cands]
            mu, gap, ov = min(gaps, key=lambda g: g[1])
            best[(S, kind)] = gap
            rows.append(
                row(
                    f"tab2/S={S}/{kind}",
                    0.0,
                    f"best_mu={mu};gap@8000={gap:.3e};mask_overlap={ov:.2f}",
                )
            )
    conv = any(
        best[(S, "regtopk")] < 1e-5 and best[(S, "topk")] > 1e-4
        for S in (0.5, 0.75)
    )
    rows.append(row("tab2/claim", 0.0, f"regtopk_converges_where_topk_not={conv}"))
    return rows
