"""Roofline terms per (arch x shape) from the dry-run artifacts.

Reads benchmarks/artifacts/dryrun.jsonl (written by repro.launch.dryrun):
  compute term    = flops / peak_flops            [per chip, s]
  memory term     = hbm_bytes / hbm_bw            [per chip, s]
  collective term = collective_bytes / ici_bw     [per chip, s]
plus MODEL_FLOPS = 6 N_active D (train) / 2 N_active (decode per token)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPS.
"""
from __future__ import annotations

import json
import os
from typing import Dict

from benchmarks.common import row
from repro import configs as cfglib
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun.jsonl")


def count_params(cfg) -> Dict[str, float]:
    """Analytic parameter counts (total and per-token-active)."""
    d, V = cfg.d_model, cfg.padded_vocab
    emb = V * d
    attn = (
        d * cfg.n_heads * cfg.hd * 2
        + d * cfg.n_kv_heads * cfg.hd * 2
    ) if cfg.n_heads else 0
    if cfg.is_moe:
        expert = 3 * d * cfg.d_ff
        shared = 3 * d * (cfg.moe_shared_d_ff or 0)
        mlp_total = cfg.n_experts * expert + shared + d * cfg.n_experts
        mlp_active = cfg.moe_top_k * expert + shared + d * cfg.n_experts
    elif cfg.d_ff:
        n_mats = 3 if cfg.act == "swiglu" else 2
        mlp_total = mlp_active = n_mats * d * cfg.d_ff
    else:
        mlp_total = mlp_active = 0
    ssm = 0
    if cfg.ssm_state:
        di = cfg.d_inner
        ssm = d * (2 * di + 2 * cfg.ssm_state + di // cfg.ssm_headdim) + di * d
    if cfg.family == "hybrid":
        per = cfg.attn_every
        n_groups = cfg.n_layers // per
        n_mamba = cfg.n_layers - n_groups
        total = emb + n_mamba * ssm + (attn + mlp_total)  # shared attn once
        active = emb + n_mamba * ssm + n_groups * (attn + mlp_active)
    elif cfg.family == "ssm":
        total = active = emb + cfg.n_layers * ssm
    elif cfg.family == "encdec":
        total = active = emb + cfg.n_enc_layers * (attn + mlp_total) + (
            cfg.n_layers * (2 * attn + mlp_total)
        )
    else:
        total = emb + cfg.n_layers * (attn + mlp_total)
        active = emb + cfg.n_layers * (attn + mlp_active)
    return {"total": float(total), "active": float(active)}


def model_flops(arch: str, shape: str) -> float:
    """Whole-system MODEL_FLOPS (all chips) for the step."""
    cfg = cfglib.get_config(arch)
    seq, batch, kind = cfglib.INPUT_SHAPES[shape]
    p = count_params(cfg)
    if kind == "train":
        return 6.0 * p["active"] * batch * seq
    if kind == "prefill":
        return 2.0 * p["active"] * batch * seq
    return 2.0 * p["active"] * batch  # decode: one token per sequence


def load(path: str = ART):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except Exception:
                continue
            recs[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return recs


def analytic_bytes(arch: str, shape: str, mesh_model: int = 16,
                   dp: int = 16) -> float:
    """Per-chip HBM traffic estimate at TPU fusion granularity.

    Weights stream: params(+opt moments+eps) r/w; activation stream:
    ~12 materialized tensors x d_model per token per layer (fwd+bwd+remat),
    halved for the model-sharded fraction. The HLO-derived ``hbm_bytes``
    is an upper bound at CPU fusion granularity; this is the napkin lower
    estimate — both are reported, the dominant term uses this one.
    """
    cfg = cfglib.get_config(arch)
    seq, batch, kind = cfglib.INPUT_SHAPES[shape]
    p = count_params(cfg)
    dt = 2.0  # bf16
    params_chip = p["total"] * dt / mesh_model
    L = cfg.n_layers + cfg.n_enc_layers
    if kind == "train":
        tokens_chip = batch * seq / dp
        weight_stream = params_chip * 6  # fwd+bwd reads, grad/opt/eps r/w
        act_stream = tokens_chip * cfg.d_model * L * 12 * dt * 0.5
        return weight_stream + act_stream
    if kind == "prefill":
        tokens_chip = batch * seq / dp
        return params_chip + tokens_chip * cfg.d_model * L * 8 * dt * 0.5
    # decode: weights + kv-cache read per token + state r/w
    dp_eff = dp if batch % dp == 0 else 1
    if cfg.ssm_state or cfg.family == "hybrid":
        cache = 0.0
        if cfg.family == "hybrid":
            n_groups = cfg.n_layers // cfg.attn_every
            cache = (
                n_groups * batch * seq * cfg.n_kv_heads * cfg.hd
                * dt / (mesh_model * dp_eff)
            )
        state = batch * (cfg.d_inner * cfg.ssm_state) * L * dt / mesh_model
        return params_chip + cache + 2 * state
    slots = min(seq, cfg.sliding_window or seq)
    cache = (
        L * batch * slots * cfg.n_kv_heads * cfg.hd * dt
        / (mesh_model * dp_eff)
    )
    return params_chip + cache


def terms(rec, n_chips: int) -> Dict[str, float]:
    comp = rec["flops"] / PEAK_FLOPS_BF16
    mem_hlo = rec["hbm_bytes"] / HBM_BW
    memt = analytic_bytes(rec["arch"], rec["shape"]) / HBM_BW
    coll = rec["collective_bytes"]["total"] / ICI_BW
    dom = max(("compute", comp), ("memory", memt), ("collective", coll),
              key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"]) / n_chips
    return {
        "compute_s": comp,
        "memory_s": memt,
        "memory_hlo_ub_s": mem_hlo,
        "collective_s": coll,
        "dominant": dom[0],
        "model_flops_per_chip": mf,
        "useful_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
    }


def run():
    rows = []
    recs = load()
    for (arch, shape, mesh, tag), rec in sorted(recs.items()):
        if mesh != "16x16" or tag:
            continue
        n_chips = 256
        t = terms(rec, n_chips)
        rows.append(
            row(
                f"roofline/{arch}/{shape}",
                0.0,
                (
                    f"compute={t['compute_s']:.3e}s;memory={t['memory_s']:.3e}s;"
                    f"memory_hlo_ub={t['memory_hlo_ub_s']:.3e}s;"
                    f"collective={t['collective_s']:.3e}s;dominant={t['dominant']};"
                    f"useful_ratio={t['useful_ratio']:.3f};"
                    f"peakGiB={rec['mem']['peak_bytes']/2**30:.2f}"
                ),
            )
        )
    return rows
