"""Shared benchmark utilities.

Besides the CSV ``row``/``emit`` helpers, this module carries the
machine-readable side of the perf CI gate (ISSUE 5):

* :func:`write_json` — dump a bench's rows as ``{"meta": ..., "rows": ...}``
  (the ``BENCH_*.json`` artifact format `tools/check_perf.py` consumes);
* :func:`calibration_us` — a fixed XLA reference computation timed in the
  same process. CI runners and dev machines differ wildly in absolute
  speed, so the regression gate compares *calibration-normalized* timings
  (``us_per_call / calib_us``) rather than raw microseconds;
* :func:`bench_main` — the ``--json out.json`` CLI shared by the
  standalone benches.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Callable, List

import jax
import jax.numpy as jnp


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 1) -> float:
    """Best wall-time per call in microseconds (blocking on results).

    Min-of-iters, the standard microbenchmark reduction: scheduler and
    frequency noise only ever add time, so the minimum is the stable
    estimate of the code's actual cost — medians of sub-ms CPU timings
    flap 2x run to run, which the CI perf gate cannot tolerate."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return 1e6 * min(times)


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}


def emit(rows) -> None:
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


def calibration_us(iters: int = 5) -> float:
    """Machine-speed reference: a fixed 1M-element elementwise chain under
    jit. Bench timings are divided by this before the CI regression
    comparison, so a slower (or faster) runner shifts numerator and
    denominator together."""
    x = jnp.arange(1 << 20, dtype=jnp.float32)

    @jax.jit
    def ref(v):
        return jnp.tanh(v * 1e-6).sum()

    return time_call(ref, x, iters=iters)


def bench_meta(bench: str) -> dict:
    return {
        "bench": bench,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "calib_us": calibration_us(),
    }


def write_json(path: str, bench: str, rows: List[dict]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"meta": bench_meta(bench), "rows": rows}, f, indent=1)
    print(f"# wrote {path} ({len(rows)} rows)")


def bench_main(run_fn: Callable[[], List[dict]], bench: str) -> None:
    """Standalone-bench entry point: CSV to stdout, plus the
    ``BENCH_*.json`` artifact when ``--json`` is given."""
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write rows (+ calibration meta) as JSON for the CI "
             "perf gate (tools/check_perf.py)",
    )
    args = ap.parse_args()
    rows = run_fn()
    print("name,us_per_call,derived")
    emit(rows)
    if args.json:
        write_json(args.json, bench, rows)
