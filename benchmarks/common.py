"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return 1e6 * times[len(times) // 2]


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}


def emit(rows) -> None:
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
