"""Communication-volume table (paper Sec. 2.2: S ~= k/J compression).

Per-round, per-worker wire volume for each architecture's J at the assigned
sparsities: the words table (dense vs fp32-COO allgather, derived from the
codec's exact ``wire_bits`` — the migration off the removed
``cost.wire_words_per_worker`` is documented in ``docs/comm.md``) plus the
``repro.comm`` codec bytes through the alpha–beta cost model — the quantity
the paper's technique actually reduces. Cross-checked against the dry-run
HLO collective bytes in EXPERIMENTS.md; the codec x strategy numerics sweep
lives in ``comm_bench``.
"""
from __future__ import annotations

from benchmarks.common import row
from benchmarks.roofline import count_params
from repro import comm, configs as cfglib

N_WORKERS = 16


def run():
    rows = []
    coo = comm.get_codec("coo_fp32")
    for arch in sorted(cfglib.ARCHS):
        if arch == "paper-resnet-proxy":
            continue
        cfg = cfglib.get_config(arch)
        J = int(count_params(cfg)["total"])
        for S in (0.01, 0.001):
            k = max(1, int(S * J))
            # uplink words/worker: dense sends the J-vector; the fp32-COO
            # allgather moves every worker's 2k-word payload (N·64k bits).
            dense = J
            sparse = N_WORKERS * int(coo.wire_bits(J, k)) // 32
            codec_bytes = ";".join(
                "{}_B={}".format(
                    name,
                    comm.predicted_bytes(
                        name, "sparse_allgather", J, k, (N_WORKERS,)
                    ),
                )
                for name in sorted(comm.CODECS)
            )
            rows.append(
                row(
                    f"comm/{arch}/S={S}",
                    0.0,
                    f"J={J};dense_words={dense};sparse_words={sparse};"
                    f"allgather_reduction={dense / sparse:.1f}x;"
                    f"uplink_reduction={J / (2 * k):.0f}x;{codec_bytes}",
                )
            )
    return rows
