"""Straggler sweep — convergence gap vs dropout rate x sparsity (ISSUE 4).

The paper's Fig. 4 heterogeneity setup (N = 20 linear-regression workers
with disjoint heterogeneous data, S = 0.6), extended along the new
participation axis: every round, a schedule drops part of the fleet and
the server aggregates the survivors with renormalized weights. RegTop-k's
posterior conditions on the *actual* broadcast, so partial participation
perturbs exactly the statistic the paper's regularizer relies on — this
sweep measures how much of RegTop-k's advantage over Top-k survives.

Rows: ``straggler/<schedule>/<kind>/S=<s>`` with the distance-to-optimum
gap after the run, plus partial-round wire-cost rows asserting the cost
model prices a dropped round strictly below a full one.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J = 20, 100
STEPS = 1500
SPARSITIES = (0.3, 0.6)
SCHEDULES = {
    "full": None,
    "drop0.25": comm.Participation("bernoulli", drop_rate=0.25, seed=1),
    "drop0.5": comm.Participation("bernoulli", drop_rate=0.5, seed=1),
    "rr2": comm.Participation("round_robin", n_stragglers=2),
    "stale2x0.5": comm.Participation(
        "stale", n_stragglers=2, staleness=2, discount=0.5
    ),
}


def _gap(kind, sparsity, participation, mu=16.0):
    data = make_linreg(7, N, J, 500, sigma2=2.0, homogeneous=False)
    cfg = SparsifierConfig(kind=kind, sparsity=sparsity, mu=mu)
    sim = DistributedSim(
        linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2,
        collective="sparse_allgather", participation=participation,
    )
    _, tr = sim.run(
        jnp.zeros(J), STEPS,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    return float(np.asarray(tr)[-1])


def run():
    rows = []
    for S in SPARSITIES:
        gaps = {}
        for sched_name, part in SCHEDULES.items():
            for kind in ("topk", "regtopk"):
                g = _gap(kind, S, part)
                gaps[(sched_name, kind)] = g
                rows.append(
                    row(
                        f"straggler/{sched_name}/{kind}/S={S}",
                        0.0,
                        f"gap@{STEPS}={g:.3e}",
                    )
                )
        assert all(np.isfinite(g) for g in gaps.values()), gaps
        # headline: how much each kind degrades relative to its own
        # full-participation gap (1.0 = unaffected by stragglers)
        for sched_name in SCHEDULES:
            if sched_name == "full":
                continue
            for kind in ("topk", "regtopk"):
                ratio = gaps[(sched_name, kind)] / max(
                    gaps[("full", kind)], 1e-12
                )
                rows.append(
                    row(
                        f"straggler/degrade/{sched_name}/{kind}/S={S}",
                        0.0,
                        f"gap_ratio_vs_full={ratio:.2f}",
                    )
                )

    # partial rounds must be priced strictly below full rounds (the axis
    # autotune trades against dropout rate). The model prices the
    # synchronous collective's critical path: for dropping schedules the
    # byte savings are real; for 'stale' the stragglers' payload bytes
    # are delayed, not saved (amortized volume is unchanged), so only the
    # per-round latency figure is asserted there.
    k = int(0.01 * 10**6)
    full = comm.predict("coo_fp32", "sparse_allgather", 10**6, k, (N,))
    for sched_name, part in SCHEDULES.items():
        if part is None:
            continue
        p = part.expected_participants(N)
        partial = comm.predict(
            "coo_fp32", "sparse_allgather", 10**6, k, (N,), participants=p
        )
        assert partial.seconds < full.seconds, sched_name
        if not part.delays_payloads:
            assert partial.bytes_on_wire < full.bytes_on_wire, sched_name
        rows.append(
            row(
                f"straggler/cost/{sched_name}",
                0.0,
                f"round_bytes={partial.bytes_on_wire}/{full.bytes_on_wire}"
                + ("(delayed,not saved)" if part.delays_payloads else ""),
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
