"""Paper Fig. 6 / Table 1 proxy — sparsified distributed NN training.

The paper trains ResNet-18/CIFAR-10 (8 workers, S in {1%, 0.1%}) and
fine-tunes 5 CV models on ImageNette, showing RegTop-k >= Top-k with the
gap widening as S decreases. Offline container -> proxy: a compact
transformer LM on *heterogeneous* synthetic data (per-worker shifted token
marginals — the cancellation regime the paper targets), 8 workers,
distributed SGD, identical init/seed for all sparsifiers, exact
whole-model top-k per worker (paper-faithful global selection via
ravel_pytree over the full parameter vector).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from benchmarks.common import row
from repro.core import aggregate, make_sparsifier, SparsifierConfig
from repro.models import ModelConfig, get_family

N_WORKERS = 8
STEPS = 50
BATCH, SEQ = 4, 32

CFG = ModelConfig(
    name="fig6-proxy",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    remat=False,
)
MOD = get_family(CFG)


def _worker_batch(step, n):
    """Heterogeneous: worker n's tokens live in a shifted vocab band."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(9), step), n)
    V = CFG.vocab
    u = jax.random.uniform(key, (BATCH, SEQ))
    tokens = ((u * V * 0.25).astype(jnp.int32) + n * (V // N_WORKERS)) % V
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def _train(kind, sparsity, mu=1.0, steps=STEPS, lr=0.05):
    params0, _ = MOD.init(jax.random.PRNGKey(0), CFG)
    theta0, unravel = ravel_pytree(params0)
    J = theta0.shape[0]
    sp = make_sparsifier(
        SparsifierConfig(kind=kind, sparsity=sparsity, mu=mu, omega=1.0 / N_WORKERS)
    )
    weights = jnp.full((N_WORKERS,), 1.0 / N_WORKERS)
    widx = jnp.arange(N_WORKERS)

    def local_grad(theta, n, t):
        batch = _worker_batch(t, n)
        loss = lambda p: MOD.loss_fn(p, CFG, batch)[0]
        return ravel_pytree(jax.grad(loss)(unravel(theta)))[0]

    def mean_loss(theta, t):
        return jnp.mean(
            jax.vmap(
                lambda n: MOD.loss_fn(unravel(theta), CFG, _worker_batch(t, n))[0]
            )(widx)
        )

    @jax.jit
    def one_step(theta, ws, g_prev, t):
        grads = jax.vmap(lambda n: local_grad(theta, n, t))(widx)
        ghat, _, ws = jax.vmap(sp.step, in_axes=(0, 0, None))(ws, grads, g_prev)
        g_agg = aggregate.dense_mean(ghat, weights)
        return theta - lr * g_agg, ws, g_agg

    single = sp.init(J)
    ws = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (N_WORKERS,) + x.shape), single
    )
    theta, g_prev = theta0, jnp.zeros(J)
    for t in range(steps):
        theta, ws, g_prev = one_step(theta, ws, g_prev, t)
    evals = [float(mean_loss(theta, t)) for t in range(steps, steps + 3)]
    return float(np.mean(evals))


def run():
    rows = []
    finals = {}
    for S in (0.01, 0.001):
        for kind in ("topk", "regtopk", "coordtopk"):
            final = _train(kind, S)
            finals[(S, kind)] = final
            rows.append(
                row(f"fig6_proxy/S={S}/{kind}", 0.0, f"eval_loss={final:.4f}")
            )
        ok = finals[(S, "regtopk")] <= finals[(S, "topk")] + 0.05
        rows.append(
            row(f"fig6_proxy/S={S}/claim", 0.0, f"regtopk_not_worse={ok}")
        )
    return rows
