"""Adaptive-k fronts — bytes-on-wire vs distance-to-optimum (ISSUE 8).

The paper fixes S = k/J per run; the adaptive controller
(:mod:`repro.comm.controller`) spends wire bytes only when the error
budget demands them. This bench draws both fronts on the Fig-3 linear
regression: a grid of *static* sparsities (each point = one whole run at
fixed k) against a grid of *error budgets* (each point = one adaptive run
whose k trajectory the controller chose), with bytes priced per round at
the round's **effective** k through :func:`repro.comm.round_wire_bits` —
``Codec.wire_bits`` keeps the pricing codec-agnostic.

Rows: ``adaptive/static/<kind>/S=...`` and ``adaptive/budget=...`` carry
``gap@STEPS`` and total per-worker MB in ``derived`` (accounting rows,
us = 0); ``adaptive/step`` times the jitted adaptive round itself — the
dynamic-k machinery rides the perf gate alongside the static benches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro import comm
from repro.core import DistributedSim, SparsifierConfig
from repro.data.pipeline import linreg_grad_fn, make_linreg

N, J = 10, 200
STEPS = 600
CODEC = "coo_fp32"
K_MAX = 0.25  # adaptive capacity: a quarter of the leaf
STATIC_S = (0.02, 0.05, 0.1, 0.25)
# the closed loop equilibrates ||eps||/||g_agg|| ~= budget on this
# problem (plateau error feedback), so the grid brackets the static
# sparsity fronts: ~2 saturates near k_max, ~10 hugs k_min
BUDGETS = (2.0, 5.0, 10.0)


def _make_sim(cfg, adaptive=None):
    data = make_linreg(3, N, J, 400, sigma2=2.0, homogeneous=False)
    sim = DistributedSim(
        linreg_grad_fn(data), N, J, cfg, learning_rate=1e-2,
        collective="sparse_allgather", codec=CODEC, adaptive_k=adaptive,
    )
    return sim, data


def _static_point(kind: str, S: float):
    cfg = SparsifierConfig(kind=kind, sparsity=S, mu=16.0)
    sim, data = _make_sim(cfg)
    _, tr = sim.run(
        jnp.zeros(J), STEPS,
        trace_fn=lambda th: jnp.linalg.norm(th - data.theta_star),
    )
    k = max(1, int(np.ceil(S * J) - 1e-9))
    bytes_total = STEPS * comm.round_wire_bits(CODEC, J, k) // 8
    return float(np.asarray(tr)[-1]), bytes_total


def _adaptive_point(budget: float):
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.05, mu=16.0)
    ctrl = comm.AdaptiveKController(budget=budget, k_min=1, k_max=K_MAX)
    sim, data = _make_sim(cfg, adaptive=ctrl)
    state0 = sim.init(jnp.zeros(J))
    _, tr = sim.run(
        jnp.zeros(J), STEPS,
        trace_state_fn=lambda s: (
            jnp.linalg.norm(s.theta - data.theta_star), s.ctrl.k
        ),
    )
    gaps, ks_next = np.asarray(tr[0]), np.asarray(tr[1])
    # round t sends the k planned after round t-1; round 0 the init k
    ks_used = np.concatenate([[int(state0.ctrl.k)], ks_next[:-1]])
    bytes_total = sum(
        comm.round_wire_bits(CODEC, J, int(k)) for k in ks_used
    ) // 8
    return float(gaps[-1]), bytes_total, int(ks_next[-1])


def run():
    rows = []
    fronts = {}
    for kind in ("topk", "regtopk"):
        for S in STATIC_S:
            gap, b = _static_point(kind, S)
            fronts[(kind, S)] = (gap, b)
            rows.append(row(
                f"adaptive/static/{kind}/S={S}", 0.0,
                f"gap@{STEPS}={gap:.3e} wire_MB={b / 1e6:.3f}",
            ))
    for budget in BUDGETS:
        gap, b, k_last = _adaptive_point(budget)
        fronts[("budget", budget)] = (gap, b)
        rows.append(row(
            f"adaptive/budget={budget}", 0.0,
            f"gap@{STEPS}={gap:.3e} wire_MB={b / 1e6:.3f} k_final={k_last}",
        ))
    assert all(np.isfinite(g) for g, _ in fronts.values()), fronts
    # the controller never prices above its own capacity ceiling
    cap_bytes = STEPS * comm.round_wire_bits(
        CODEC, J, int(np.ceil(K_MAX * J))
    ) // 8
    assert all(
        b <= cap_bytes for key, (_, b) in fronts.items() if key[0] == "budget"
    )

    # timed row: one jitted adaptive round (dynamic-k selection + control
    # law), state threaded to keep the measurement honest
    cfg = SparsifierConfig(kind="regtopk", sparsity=0.05, mu=16.0)
    ctrl = comm.AdaptiveKController(budget=1.0, k_min=1, k_max=K_MAX)
    sim, _ = _make_sim(cfg, adaptive=ctrl)
    step = jax.jit(lambda s: sim.step_fn(s)[0])
    state = step(sim.init(jnp.zeros(J)))  # warm the cache + advance once
    us = time_call(step, state, iters=10)
    rows.append(row("adaptive/step", us, f"N={N} J={J} cap={K_MAX}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run, "adaptive_bench")
